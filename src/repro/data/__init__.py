from repro.data.synthetic import synth_mnist, synth_tokens
from repro.data.federated_split import iid_split, dirichlet_split
from repro.data.pipeline import batch_iterator, FederatedDataset

__all__ = [
    "synth_mnist",
    "synth_tokens",
    "iid_split",
    "dirichlet_split",
    "batch_iterator",
    "FederatedDataset",
]
