"""Deterministic synthetic datasets (the container is offline — no MNIST).

``synth_mnist`` produces an MNIST-shaped 10-class problem: each class has a
prototype image built from smooth random blobs; samples are prototypes +
per-sample deformation + pixel noise, clipped to [0, 1]. It is learnable to
high accuracy by the paper's 784-500-100-10 MLP, hard enough that accuracy
climbs over tens of rounds (like Fig 2), and exactly reproducible from the
seed. DESIGN.md documents the substitution.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _class_prototypes(rng: np.random.Generator, num_classes: int, side: int = 28) -> np.ndarray:
    """Smooth blob prototypes, one per class."""
    protos = np.zeros((num_classes, side, side), np.float32)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32)
    for c in range(num_classes):
        img = np.zeros((side, side), np.float32)
        for _ in range(4):  # a few gaussian strokes per class
            cx, cy = rng.uniform(4, side - 4, size=2)
            sx, sy = rng.uniform(2.0, 5.0, size=2)
            amp = rng.uniform(0.6, 1.0)
            img += amp * np.exp(-(((xx - cx) / sx) ** 2 + ((yy - cy) / sy) ** 2))
        protos[c] = img / max(img.max(), 1e-6)
    return protos


def synth_mnist(
    num_train: int = 60000,
    num_test: int = 10000,
    num_classes: int = 10,
    seed: int = 0,
    noise: float = 0.25,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test); x flattened to 784."""
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, num_classes)
    side = protos.shape[-1]

    def make(n: int, rng: np.random.Generator):
        y = rng.integers(0, num_classes, size=n)
        x = protos[y].copy()
        # per-sample smooth deformation: random shift + scale
        shifts = rng.integers(-2, 3, size=(n, 2))
        for i in range(n):
            x[i] = np.roll(x[i], tuple(shifts[i]), axis=(0, 1))
        x *= rng.uniform(0.7, 1.3, size=(n, 1, 1)).astype(np.float32)
        x += noise * rng.standard_normal((n, side, side)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0)
        return x.reshape(n, side * side).astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = make(num_train, rng)
    x_te, y_te = make(num_test, rng)
    return x_tr, y_tr, x_te, y_te


def synth_tokens(
    num_sequences: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
) -> np.ndarray:
    """Markov-ish synthetic token stream for LM smoke training: next token is
    a noisy function of the previous one, so there is signal to learn."""
    rng = np.random.default_rng(seed)
    # sparse deterministic successor table + noise
    successor = rng.integers(0, vocab, size=vocab)
    toks = np.empty((num_sequences, seq_len), np.int32)
    cur = rng.integers(0, vocab, size=num_sequences)
    for t in range(seq_len):
        toks[:, t] = cur
        noise = rng.random(num_sequences) < 0.2
        cur = np.where(noise, rng.integers(0, vocab, size=num_sequences), successor[cur])
    return toks
