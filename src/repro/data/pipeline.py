"""Batching / iteration utilities, deterministic from seeds."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np


def batch_iterator(
    x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0, epochs: int | None = None
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Shuffled minibatch stream; loops forever when epochs is None."""
    rng = np.random.default_rng(seed)
    epoch = 0
    n = len(x)
    while epochs is None or epoch < epochs:
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = perm[i : i + batch_size]
            yield x[sel], y[sel]
        epoch += 1


@dataclasses.dataclass
class FederatedDataset:
    """Per-agent shards + a deterministic per-agent batch stream."""

    shards: List[Tuple[np.ndarray, np.ndarray]]
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        self._iters: Dict[int, Iterator] = {}

    def num_agents(self) -> int:
        return len(self.shards)

    def next_batch(self, agent: int) -> Tuple[np.ndarray, np.ndarray]:
        if agent not in self._iters:
            x, y = self.shards[agent]
            bs = min(self.batch_size, len(x))
            self._iters[agent] = batch_iterator(x, y, bs, seed=self.seed + agent)
        return next(self._iters[agent])
