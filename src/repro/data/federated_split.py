"""Federated dataset partitioning across agents.

The paper splits MNIST uniformly: '60000/|A| samples ... the probability of
one sample to belong to one class is the same for every agent' (IID). We also
provide the standard Dirichlet non-IID split for beyond-paper experiments.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def iid_split(
    x: np.ndarray, y: np.ndarray, num_agents: int, seed: int = 0
) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    shards = np.array_split(perm, num_agents)
    return [(x[s], y[s]) for s in shards]


def dirichlet_split(
    x: np.ndarray,
    y: np.ndarray,
    num_agents: int,
    alpha: float = 0.5,
    seed: int = 0,
    num_classes: int | None = None,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Non-IID: each class's samples distributed over agents ~ Dirichlet(alpha)."""
    rng = np.random.default_rng(seed)
    if num_classes is None:
        num_classes = int(y.max()) + 1
    idx_per_agent: List[List[int]] = [[] for _ in range(num_agents)]
    for c in range(num_classes):
        idx_c = np.where(y == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet([alpha] * num_agents)
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for a, chunk in enumerate(np.split(idx_c, cuts)):
            idx_per_agent[a].extend(chunk.tolist())
    out = []
    for a in range(num_agents):
        sel = np.array(sorted(idx_per_agent[a]), dtype=int)
        out.append((x[sel], y[sel]))
    return out
