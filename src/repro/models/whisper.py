"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model). The transformer backbone is
real: bidirectional encoder, causal decoder with cross-attention, LayerNorm +
GELU (the Whisper recipe), sinusoidal encoder positions, learned decoder
positions, tied unembedding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.param_defs import ParamDef, axes_tree, init_tree, shape_tree, stack_defs, count_params
from repro.models.sharding_hooks import shard_act


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str = "whisper-base"
    vocab: int = 51865
    d_model: int = 512
    n_heads: int = 8
    kv_heads: int = 8
    d_ff: int = 2048
    enc_layers: int = 6
    dec_layers: int = 6
    max_positions: int = 4096
    remat: bool = True
    subquadratic: bool = False
    mrope: bool = False
    sharding_overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return self.enc_layers + self.dec_layers


def _attn_spec(cfg: WhisperConfig, causal: bool) -> L.AttnSpec:
    return L.AttnSpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        kv_heads=cfg.kv_heads,
        head_dim=cfg.head_dim,
        causal=causal,
        rope="none",
        bias=True,
    )


def _sinusoid(S: int, d: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (dim / max(d // 2 - 1, 1)))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


class WhisperModel:
    def __init__(self, cfg: WhisperConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------
    def _enc_layer_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": L.init_layernorm(cfg.d_model),
            "attn": L.init_attention(_attn_spec(cfg, causal=False)),
            "ln2": L.init_layernorm(cfg.d_model),
            "mlp": L.init_mlp(L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu")),
        }

    def _dec_layer_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": L.init_layernorm(cfg.d_model),
            "self_attn": L.init_attention(_attn_spec(cfg, causal=True)),
            "ln2": L.init_layernorm(cfg.d_model),
            "cross_attn": L.init_attention(_attn_spec(cfg, causal=False)),
            "ln3": L.init_layernorm(cfg.d_model),
            "mlp": L.init_mlp(L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu")),
        }

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": L.init_embedding(cfg.vocab, cfg.d_model),
            "pos_dec": ParamDef((cfg.max_positions, cfg.d_model), (None, "embed"), init="embed", scale=0.01),
            "enc": stack_defs(self._enc_layer_defs(), cfg.enc_layers),
            "dec": stack_defs(self._dec_layer_defs(), cfg.dec_layers),
            "enc_ln": L.init_layernorm(cfg.d_model),
            "dec_ln": L.init_layernorm(cfg.d_model),
        }

    def init(self, seed: int = 0):
        return init_tree(self.param_defs(), jax.random.PRNGKey(seed))

    def axes(self):
        return axes_tree(self.param_defs())

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def num_params(self) -> int:
        return count_params(self.param_shapes())

    def num_active_params(self) -> int:
        # exclude gather-only tables (pos embeddings); embed table counted
        # once for the unembed matmul
        shapes = self.param_shapes()
        return count_params({"enc": shapes["enc"], "dec": shapes["dec"], "embed": shapes["embed"]})

    # -- encoder ----------------------------------------------------------------
    def encode(self, params, enc_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        B, S, D = enc_embeds.shape
        x = enc_embeds + jnp.asarray(_sinusoid(S, D))[None].astype(enc_embeds.dtype)
        x = shard_act(x, ("batch", "act_seq", "embed"))
        spec = _attn_spec(cfg, causal=False)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        def body(x, p):
            h = L.layer_norm(p["ln1"], x)
            x = x + L.apply_attention(p["attn"], spec, h, positions)
            h = L.layer_norm(p["ln2"], x)
            x = x + L.apply_mlp(p["mlp"], L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu"), h)
            x = shard_act(x, ("batch", "act_seq", "embed"))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return L.layer_norm(params["enc_ln"], x)

    # -- decoder (teacher forcing) -----------------------------------------------
    def _cross_attend(self, p, spec, h, enc_k, enc_v):
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"]) + p["bq"]
        out = L._sdpa(q, enc_k, enc_v, None, spec.n_heads // spec.kv_heads)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    def _enc_kv(self, p, enc_out):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"]) + p["bk"]
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"]) + p["bv"]
        return k, v

    def decode_stack(self, params, tokens, enc_out, pos_offset: int = 0):
        cfg = self.cfg
        B, S = tokens.shape
        pos_ids = jnp.arange(S) + pos_offset
        x = L.embed(params["embed"], tokens) + params["pos_dec"][pos_ids][None].astype(jnp.bfloat16)
        x = shard_act(x, ("batch", "act_seq", "embed"))
        spec = _attn_spec(cfg, causal=True)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        def body(x, p):
            h = L.layer_norm(p["ln1"], x)
            x = x + L.apply_attention(p["self_attn"], spec, h, positions)
            h = L.layer_norm(p["ln2"], x)
            ek, ev = self._enc_kv(p["cross_attn"], enc_out)
            x = x + self._cross_attend(p["cross_attn"], spec, h, ek, ev)
            h = L.layer_norm(p["ln3"], x)
            x = x + L.apply_mlp(p["mlp"], L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu"), h)
            x = shard_act(x, ("batch", "act_seq", "embed"))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["dec"])
        return L.layer_norm(params["dec_ln"], x)

    def _logits(self, params, x):
        return jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"], preferred_element_type=jnp.float32
        ).astype(jnp.bfloat16)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        enc_out = self.encode(params, batch["enc_embeds"])
        tokens = batch["tokens"]
        x = self.decode_stack(params, tokens, enc_out)
        logits = self._logits(params, x[:, :-1])
        targets = tokens[:, 1:].astype(jnp.int32)
        from repro.models.transformer import _sharded_ce

        per_ex = jnp.mean(_sharded_ce(logits, targets), axis=-1)
        return per_ex, {}

    # -- serving -------------------------------------------------------------------
    def cache_defs(self, batch: int, cache_len: int, enc_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        per_layer = {
            "k": ParamDef((batch, cache_len, cfg.kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros"),
            "v": ParamDef((batch, cache_len, cfg.kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros"),
            "ek": ParamDef((batch, enc_len, cfg.kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros"),
            "ev": ParamDef((batch, enc_len, cfg.kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros"),
        }
        return {"dec": stack_defs(per_layer, cfg.dec_layers)}

    def init_cache(self, batch: int, cache_len: int, enc_len: int):
        return init_tree(self.cache_defs(batch, cache_len, enc_len), jax.random.PRNGKey(0))

    def cache_axes(self, batch: int, cache_len: int, enc_len: int):
        return axes_tree(self.cache_defs(batch, cache_len, enc_len))

    def prefill(self, params, batch):
        """Encode + run the decoder prompt, building self- and cross-KV caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_embeds"])
        tokens = batch["tokens"]
        B, Sq = tokens.shape
        cache_len = batch.get("cache_len", Sq)
        spec = _attn_spec(cfg, causal=True)
        pos_ids = jnp.arange(Sq)
        x = L.embed(params["embed"], tokens) + params["pos_dec"][pos_ids][None].astype(jnp.bfloat16)
        positions = jnp.broadcast_to(pos_ids[None, :], (B, Sq))

        def body(x, p):
            h = L.layer_norm(p["ln1"], x)
            q, k, v = L._proj_qkv(p["self_attn"], spec, h)
            mask = L.causal_mask(Sq, Sq)
            out = L._sdpa(q, k, v, mask, 1)
            x = x + jnp.einsum("bshk,hkd->bsd", out, p["self_attn"]["wo"])
            h = L.layer_norm(p["ln2"], x)
            ek, ev = self._enc_kv(p["cross_attn"], enc_out)
            x = x + self._cross_attend(p["cross_attn"], spec, h, ek, ev)
            h = L.layer_norm(p["ln3"], x)
            x = x + L.apply_mlp(p["mlp"], L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu"), h)
            kc = jnp.zeros((B, cache_len) + k.shape[2:], k.dtype)
            vc = jnp.zeros_like(kc)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
            return x, {"k": kc, "v": vc, "ek": ek, "ev": ev}

        x, cache = jax.lax.scan(body, x, params["dec"])
        x = L.layer_norm(params["dec_ln"], x)
        logits = self._logits(params, x[:, -1:])
        return logits, {"dec": cache}

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        token, pos = batch["token"], batch["pos"]
        B = token.shape[0]
        spec = _attn_spec(cfg, causal=True)
        x = L.embed(params["embed"], token) + params["pos_dec"][pos][None, None].astype(jnp.bfloat16)

        def body(x, slices):
            p, c = slices
            h = L.layer_norm(p["ln1"], x)
            y, nc = L.decode_attention(p["self_attn"], spec, h, {"k": c["k"], "v": c["v"]}, pos)
            x = x + y
            h = L.layer_norm(p["ln2"], x)
            x = x + self._cross_attend(p["cross_attn"], spec, h, c["ek"], c["ev"])
            h = L.layer_norm(p["ln3"], x)
            x = x + L.apply_mlp(p["mlp"], L.MLPSpec(cfg.d_model, cfg.d_ff, "gelu"), h)
            return x, {"k": nc["k"], "v": nc["v"], "ek": c["ek"], "ev": c["ev"]}

        x, new_dec = jax.lax.scan(body, x, (params["dec"], cache["dec"]))
        x = L.layer_norm(params["dec_ln"], x)
        logits = self._logits(params, x)
        return logits, {"dec": new_dec}
