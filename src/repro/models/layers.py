"""Composable transformer layers: norms, RoPE (std / M-RoPE), attention
(full / GQA / sliding-window / MLA), GLU MLPs, MoE with sort-based dispatch.

Everything is a pair of functions:
    init_<block>(cfg-ish args)            -> nested dict of ParamDef
    apply_<block>(params, x, ...)         -> y (and cache for attention)
Attention supports three modes:
    train/prefill: full-sequence causal (or bidirectional) attention;
    decode:        one new token against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param_defs import ParamDef
from repro.models.sharding_hooks import shard_act

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rms_norm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int) -> Dict[str, ParamDef]:
    return {
        "scale": ParamDef((d,), (None,), init="ones"),
        "bias": ParamDef((d,), (None,), init="zeros"),
    }


def layer_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(rotary_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, rotary_dim, 2, dtype=np.float32) / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rotary_dim: Optional[int] = None) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    freqs = jnp.asarray(rope_freqs(rd, theta))  # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,rd/2)
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rd:]], axis=-1)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float = 1000000.0,
                sections: Tuple[int, int, int] = (16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions3 (3, ..., S) = (t, h, w) ids;
    the rotary spectrum is split into three sections, one per component.
    ``sections`` are in units of freq pairs and must sum to hd/2."""
    hd = x.shape[-1]
    assert sum(sections) * 2 == hd, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    # pick a position component per frequency band
    comp = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (hd/2,)
    pos = jnp.take(positions3.astype(jnp.float32), comp, axis=0)  # (hd/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)  # (..., S, hd/2)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / sliding / bidirectional / cross)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    window: Optional[int] = None        # sliding-window size (None = full)
    causal: bool = True                  # False for encoder self-attention
    rope: str = "std"                    # "std" | "mrope" | "none"
    rope_theta: float = 10000.0
    qk_norm: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    bias: bool = False


def init_attention(s: AttnSpec) -> Dict[str, Any]:
    d, h, kv, hd = s.d_model, s.n_heads, s.kv_heads, s.head_dim
    defs: Dict[str, Any] = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if s.bias:
        defs["bq"] = ParamDef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
    if s.qk_norm:
        defs["q_norm"] = init_rmsnorm(hd)
        defs["k_norm"] = init_rmsnorm(hd)
    return defs


def _proj_qkv(params, s: AttnSpec, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if s.bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if s.qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    return q, k, v


def _rope_qk(s: AttnSpec, q, k, positions):
    if s.rope == "std":
        q = apply_rope(q, positions, s.rope_theta)
        k = apply_rope(k, positions, s.rope_theta)
    elif s.rope == "mrope":
        q = apply_mrope(q, positions, s.rope_theta, s.mrope_sections)
        k = apply_mrope(k, positions, s.rope_theta, s.mrope_sections)
    return q, k


def _sdpa(q, k, v, mask, n_rep: int) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask broadcastable to (B,1,S,T)."""
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, v)


def causal_mask(S: int, T: int, window: Optional[int] = None, offset: int = 0):
    """(1,1,S,T) mask; offset = query position of row 0 within the T axis."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m[None, None]


def apply_attention(
    params,
    s: AttnSpec,
    x: jax.Array,
    positions: jax.Array,
    mask: Optional[jax.Array] = None,
    seq_parallel: bool = False,
) -> jax.Array:
    """Full-sequence self-attention (train / prefill).

    Two distribution schemes, chosen by the caller:
      * head-parallel (default): block input was all-gathered over seq;
        q/k/v head-sharded over "model"; wo contraction emits a psum.
      * seq-parallel: for archs whose head count does not divide the model
        axis (minitron/phi4: 24 heads, gemma3: 4). q stays sequence-sharded;
        only the (small, GQA) k/v are gathered in bf16 — for kv=8 of 24
        heads that is 2x134MB instead of 3x805MB f32 per layer.
    """
    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, s, x)
    q, k = _rope_qk(s, q, k, positions)
    if seq_parallel:
        q = shard_act(q, ("batch", "act_seq", None, None))
        k = shard_act(k, ("batch", None, None, None))
        v = shard_act(v, ("batch", None, None, None))
    else:
        q = shard_act(q, ("batch", None, "heads", None))
    if mask is None and s.causal:
        mask = causal_mask(S, S, s.window)
    out = _sdpa(q, k, v, mask, s.n_heads // s.kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_attn_cache(s: AttnSpec, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """KV cache defs for decode. Sliding-window layers keep only the window
    (ring buffer); full layers keep seq_len. Logical axes mark kv_seq for
    context-parallel sharding."""
    T = min(seq_len, s.window) if s.window is not None else seq_len
    return {
        "k": ParamDef((batch, T, s.kv_heads, s.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros", dtype=dtype),
        "v": ParamDef((batch, T, s.kv_heads, s.head_dim), ("batch", "kv_seq", "kv_heads", None), init="zeros", dtype=dtype),
    }


def decode_attention(
    params,
    s: AttnSpec,
    x: jax.Array,            # (B, 1, D) the new token
    cache: Dict[str, jax.Array],
    pos: jax.Array,          # () current position (number of tokens already cached)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    q, k_new, v_new = _proj_qkv(params, s, x)
    if s.rope == "mrope":
        # text-token decode: all three position components advance together
        positions = jnp.full((3, B, 1), pos, jnp.int32)
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new = _rope_qk(s, q, k_new, positions)
    T = cache["k"].shape[1]
    slot = pos % T if s.window is not None else pos  # ring buffer for windows
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    kj = jnp.arange(T)
    if s.window is not None:
        # ring buffer: every slot is valid once the buffer has wrapped
        valid = jnp.where(pos + 1 >= T, jnp.ones((T,), bool), kj <= slot)
    else:
        valid = kj <= pos
    mask = valid.reshape(1, 1, 1, T)
    out = _sdpa(q, k, v, mask, s.n_heads // s.kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLASpec:
    d_model: int
    n_heads: int
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128
    rope_theta: float = 10000.0


def init_mla(s: MLASpec) -> Dict[str, Any]:
    d, h = s.d_model, s.n_heads
    return {
        "wq": ParamDef((d, h, s.qk_nope + s.qk_rope), ("embed", "heads", None)),
        "wdkv": ParamDef((d, s.kv_lora), ("embed", None)),
        "wk_rope": ParamDef((d, s.qk_rope), ("embed", None)),
        "kv_norm": init_rmsnorm(s.kv_lora),
        "wuk": ParamDef((s.kv_lora, h, s.qk_nope), (None, "heads", None)),
        "wuv": ParamDef((s.kv_lora, h, s.v_head), (None, "heads", None)),
        "wo": ParamDef((h, s.v_head, d), ("heads", None, "embed")),
    }


def apply_mla(params, s: MLASpec, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Training / prefill MLA."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., : s.qk_nope], q[..., s.qk_nope :]
    q_rope = apply_rope(q_rope, positions, s.rope_theta)
    latent = rms_norm(params["kv_norm"], jnp.einsum("bsd,dl->bsl", x, params["wdkv"]))
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, params["wk_rope"])[:, :, None, :], positions, s.rope_theta
    )  # (B,S,1,rope) shared across heads
    k_nope = jnp.einsum("bsl,lhk->bshk", latent, params["wuk"])
    val = jnp.einsum("bsl,lhk->bshk", latent, params["wuv"])
    scale = 1.0 / np.sqrt(s.qk_nope + s.qk_rope)
    logits = (
        jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        + jnp.einsum("bshk,bthk->bhst", q_rope, jnp.broadcast_to(k_rope, q_rope.shape[:1] + (S,) + q_rope.shape[2:]))
    ).astype(jnp.float32) * scale
    mask = causal_mask(S, S)
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, val)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_mla_cache(s: MLASpec, batch: int, seq_len: int, dtype=jnp.bfloat16):
    return {
        "latent": ParamDef((batch, seq_len, s.kv_lora), ("batch", "kv_seq", None), init="zeros", dtype=dtype),
        "k_rope": ParamDef((batch, seq_len, s.qk_rope), ("batch", "kv_seq", None), init="zeros", dtype=dtype),
    }


def decode_mla(params, s: MLASpec, x, cache, pos):
    """Absorbed-form MLA decode: score against the latent cache directly —
    per-step cost O(S * (kv_lora + qk_rope) * H) instead of re-expanding K/V."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])  # (B,1,H,nope+rope)
    q_nope, q_rope = q[..., : s.qk_nope], q[..., s.qk_nope :]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, positions, s.rope_theta)
    latent_new = rms_norm(params["kv_norm"], jnp.einsum("bsd,dl->bsl", x, params["wdkv"]))
    k_rope_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, params["wk_rope"])[:, :, None, :], positions, s.rope_theta
    )[:, :, 0, :]
    latent = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent_new.astype(cache["latent"].dtype), pos, axis=1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1
    )
    # absorb: q' = q_nope @ wuk  -> latent space
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, params["wuk"])  # (B,1,H,L)
    T = latent.shape[1]
    scale = 1.0 / np.sqrt(s.qk_nope + s.qk_rope)
    logits = (
        jnp.einsum("bshl,btl->bhst", q_lat, latent)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    valid = (jnp.arange(T)[None, :] <= pos).reshape(1, 1, 1, T)
    logits = jnp.where(valid, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btl->bshl", probs, latent)  # (B,1,H,L)
    out = jnp.einsum("bshl,lhk->bshk", o_lat, params["wuv"])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"latent": latent, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    d_model: int
    d_ff: int
    activation: str = "silu"  # silu (SwiGLU) | gelu (GeGLU) | relu2
    gated: bool = True        # False = plain 2-matrix MLP (e.g. Nemotron relu2)


def init_mlp(s: MLPSpec) -> Dict[str, Any]:
    defs = {
        "wu": ParamDef((s.d_model, s.d_ff), ("embed", "ffn")),
        "wd": ParamDef((s.d_ff, s.d_model), ("ffn", "embed")),
    }
    if s.gated:
        defs["wg"] = ParamDef((s.d_model, s.d_ff), ("embed", "ffn"))
    return defs


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def apply_mlp(params, s: MLPSpec, x: jax.Array) -> jax.Array:
    if s.gated:
        g = _act(s.activation, jnp.einsum("bsd,df->bsf", x, params["wg"]))
        u = jnp.einsum("bsd,df->bsf", x, params["wu"])
        h = g * u
    else:
        h = _act(s.activation, jnp.einsum("bsd,df->bsf", x, params["wu"]))
    h = shard_act(h, ("batch", None, "ffn"))
    return jnp.einsum("bsf,fd->bsd", h, params["wd"])


# ---------------------------------------------------------------------------
# MoE with sort-based capacity dispatch
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_expert: int
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_shared: int = 0                 # shared-expert hidden size (total)
    capacity_factor: float = 1.25
    # GShard-style floor on per-expert capacity (capped at T*K): the
    # cf-scaled capacity is relative to the balanced load T*K/E, which for a
    # decode step (T = batch) rounds to ~1 and silently drops colliding
    # tokens that prefill (large T) keeps — prefill/decode then disagree by
    # a whole expert contribution. The floor makes tiny-T dispatch lossless.
    min_capacity: int = 4
    activation: str = "silu"
    renorm: bool = True
    # dispatch groups: routing/capacity are computed PER GROUP so every
    # token-space tensor keeps a leading group dim shardable over the DP
    # axes; without this the sort/scatter tensors get replicated per device
    # (observed 224 GB/device in the dry-run). 32 = lcm of the dp extents.
    groups: int = 32


def init_moe(s: MoESpec) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "router": ParamDef((s.d_model, s.num_experts), ("embed", "experts"), scale=0.1),
        "wg": ParamDef((s.num_experts, s.d_model, s.d_expert), ("experts", "embed", "expert_ffn")),
        "wu": ParamDef((s.num_experts, s.d_model, s.d_expert), ("experts", "embed", "expert_ffn")),
        "wd": ParamDef((s.num_experts, s.d_expert, s.d_model), ("experts", "expert_ffn", "embed")),
    }
    if s.num_shared > 0:
        defs["shared"] = init_mlp(MLPSpec(s.d_model, s.d_shared, s.activation))
    return defs


def apply_moe(params, s: MoESpec, x: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Routed MoE. Under a mesh context the routed path runs inside an
    explicit shard_map (per-device dispatch + expert-parallel slicing + one
    psum) — GSPMD was observed to replicate the token-space gathers of the
    einsum formulation across all 256 devices (~50 GB/device); the shard_map
    schedule pins every tensor's placement. Without a mesh (CPU smoke tests)
    the pure-jnp grouped reference path below runs instead, and the two are
    allclose-tested against each other."""
    from repro.models.sharding_hooks import _CTX

    ctx = _CTX.get()
    if ctx is not None:
        return _apply_moe_shardmap(params, s, x, ctx)
    return _apply_moe_reference(params, s, x)


def _apply_moe_reference(params, s: MoESpec, x: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    T = B * S
    E, K = s.num_experts, s.top_k
    G = s.groups if (s.groups > 0 and T % s.groups == 0 and T >= s.groups * max(E // K, 1)) else 1
    Tg = T // G
    C = max(int(np.ceil(Tg * K / E * s.capacity_factor)), min(s.min_capacity, Tg * K))
    xg = x.reshape(G, Tg, D)
    xg = shard_act(xg, ("batch", None, "embed"))

    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(gates, K)  # (G,Tg,K)
    if s.renorm:
        top_v = top_v / jnp.maximum(jnp.sum(top_v, axis=-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * p_e (global over all groups)
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=2), axis=(0, 1)) / K
    lb_loss = E * jnp.sum(me * ce)

    # ---- group-local sort-based dispatch ---------------------------------
    # dispatch = PERMUTATION (scatter-set into capacity slots; never add, so
    # no f32 upcast); combine = gather + weighted sum over the K choices.
    TK = Tg * K
    flat_e = top_i.reshape(G, TK)                               # expert ids
    flat_t = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], (G, Tg, K)).reshape(G, TK)
    order = jnp.argsort(flat_e, axis=1)                         # stable per group
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    seg_start = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(E), side="left"))(se)  # (G,E)
    pos = jnp.arange(TK)[None, :] - jnp.take_along_axis(seg_start, se, axis=1)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                             # parking slot C

    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, TK))
    contrib = jnp.take_along_axis(xg, st[..., None], axis=1)    # (G,TK,D) bf16
    contrib = shard_act(contrib, ("batch", None, "embed"))
    buf = jnp.zeros((G, E, C + 1, D), x.dtype).at[gi, se, pos_c].set(contrib)
    buf = shard_act(buf[:, :, :C], ("batch", "experts", None, "embed"))

    g = _act(s.activation, jnp.einsum("gecd,edf->gecf", buf, params["wg"]))
    u = jnp.einsum("gecd,edf->gecf", buf, params["wu"])
    h = jnp.einsum("gecf,efd->gecd", g * u, params["wd"])
    h = shard_act(h, ("batch", "experts", None, "embed"))

    # slot of token t's k-th choice, in (G,Tg,K) layout (C = dropped)
    inv_pos = jnp.zeros((G, TK), jnp.int32).at[gi, order].set(pos_c).reshape(G, Tg, K)
    hpad = jnp.pad(h, ((0, 0), (0, 0), (0, 1), (0, 0)))          # parking slot reads 0
    picked = hpad[
        jnp.arange(G)[:, None, None],
        top_i,                                                    # (G,Tg,K)
        inv_pos,
    ]                                                             # (G,Tg,K,D)
    picked = shard_act(picked, ("batch", None, None, "embed"))
    out = jnp.einsum("gtkd,gtk->gtd", picked, top_v.astype(x.dtype))
    y = out.reshape(B, S, D)
    if s.num_shared > 0:
        y = y + apply_mlp(params["shared"], MLPSpec(s.d_model, s.d_shared, s.activation), x)
    return y, {"lb_loss": lb_loss}


def _apply_moe_shardmap(params, s: MoESpec, x: jax.Array, ctx) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Explicit schedule: every rank dispatches ITS tokens to capacity slots
    of the experts IT owns (expert-parallel mode) or of all experts with the
    ffn dim sharded (tensor-parallel mode); one psum over "model" merges the
    partial combines. Per-device capacity C = ceil(T_local*K/E*cf)."""
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, rules = ctx
    dp = rules.get("batch")
    dp_axes = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    expert_parallel = rules.get("experts") == "model" and s.num_experts % (mesh.shape.get("model", 1)) == 0
    ffn_parallel = (not expert_parallel) and rules.get("expert_ffn") == "model" and s.d_expert % mesh.shape.get("model", 1) == 0

    B, S, D = x.shape
    E, K = s.num_experts, s.top_k
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    if B % dp_size != 0:
        dp_axes, dp_size = (), 1
    T_loc = (B // dp_size) * S
    mp = mesh.shape.get("model", 1) if (expert_parallel or ffn_parallel) else 1
    E_loc = E // mp if expert_parallel else E
    C = max(int(np.ceil(T_loc * K / E * s.capacity_factor)), min(s.min_capacity, T_loc * K))

    def routed(xb, router, wg, wu, wd):
        # xb: (B_loc, S, D); wg/wu/wd expert weights, already locally sliced
        # by shard_map: expert-parallel -> (E_loc, D, F); ffn -> (E, D, F_loc)
        xf = xb.reshape(T_loc, D)
        logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        top_v, top_i = jax.lax.top_k(gates, K)
        if s.renorm:
            top_v = top_v / jnp.maximum(jnp.sum(top_v, axis=-1, keepdims=True), 1e-9)
        # load balance (local estimate; pmean over dp below)
        me = jnp.mean(gates, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=1), axis=0) / K
        lb = E * jnp.sum(me * ce)
        if dp_axes:
            lb = jax.lax.pmean(lb, dp_axes)

        flat_e = top_i.reshape(-1)                      # (T_loc*K,)
        flat_t = jnp.broadcast_to(jnp.arange(T_loc)[:, None], (T_loc, K)).reshape(-1)
        order = jnp.argsort(flat_e)
        se, st = flat_e[order], flat_t[order]
        seg = jnp.searchsorted(se, jnp.arange(E), side="left")
        pos = jnp.arange(T_loc * K) - seg[se]
        keep = pos < C
        if expert_parallel and model_ax is not None:
            r = jax.lax.axis_index(model_ax)
            local_e = se - r * E_loc
            mine = (local_e >= 0) & (local_e < E_loc) & keep
            le = jnp.where(mine, local_e, 0)
        else:
            mine = keep
            le = se
        pos_c = jnp.where(mine, pos, C)                  # parking slot
        contrib = xf[st]                                  # (T_loc*K, D)
        buf = jnp.zeros((E_loc, C + 1, D), xb.dtype).at[le, pos_c].set(contrib)
        buf = buf[:, :C]

        g = _act(s.activation, jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jnp.einsum("ecf,efd->ecd", g * u, wd)        # (E_loc, C, D)

        # combine: gather my experts' outputs back to token order; foreign
        # experts / dropped tokens read the zero parking slot; psum merges.
        hpad = jnp.pad(h, ((0, 0), (0, 1), (0, 0)))
        vals = hpad[le, pos_c]                            # (T_loc*K, D)
        sv = top_v.reshape(-1)[order]
        vals = vals * jnp.where(mine, sv, 0.0)[:, None].astype(xb.dtype)
        out = jnp.zeros((T_loc, D), jnp.float32).at[st].add(vals.astype(jnp.float32))
        if model_ax is not None and (expert_parallel or ffn_parallel):
            out = jax.lax.psum(out, model_ax)
        return out.reshape(xb.shape).astype(xb.dtype), lb

    dpP = dp if dp_axes else None
    if expert_parallel:
        w_spec = P("model", None, None)
        wd_spec = P("model", None, None)
    elif ffn_parallel:
        w_spec = P(None, None, "model")
        wd_spec = P(None, "model", None)
    else:
        w_spec = P(None, None, None)
        wd_spec = P(None, None, None)

    routed_sm = shard_map(
        routed,
        mesh=mesh,
        in_specs=(P(dpP, None, None), P(None, None), w_spec, w_spec, wd_spec),
        out_specs=(P(dpP, None, None), P()),
        check_vma=False,
    )
    y, lb_loss = routed_sm(x, params["router"], params["wg"], params["wu"], params["wd"])
    if s.num_shared > 0:
        y = y + apply_mlp(params["shared"], MLPSpec(s.d_model, s.d_shared, s.activation), x)
    return y, {"lb_loss": lb_loss}


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embedding(vocab: int, d_model: int) -> Dict[str, Any]:
    return {"table": ParamDef((vocab, d_model), ("vocab", "embed"), init="embed", scale=0.02)}


def embed(params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table^T (fp32)."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), params["table"].astype(jnp.float32))
