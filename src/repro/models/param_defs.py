"""Parameter-definition system: shapes + logical sharding axes + init, in one
declaration. Every model builds a nested dict of ``ParamDef``; from it we get
  * init_tree(defs, key)  -> params pytree (concrete arrays)
  * axes_tree(defs)       -> matching pytree of logical-axis tuples
  * shape_tree(defs)      -> matching pytree of jax.ShapeDtypeStruct
The axes tuples feed core/sharded.py's logical→mesh mapping (IPLS partition
plane); the shape tree feeds the allocation-free dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed | small
    scale: float = 1.0           # multiplier on the default fan-in scale
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)
    # fan-in scaled normal (truncation unnecessary for smoke scale)
    fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    if len(d.shape) >= 3:
        fan_in = int(np.prod(d.shape[:-1])) // d.shape[-1] if d.init == "small" else d.shape[0]
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_tree(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def shape_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Stack a period's defs n times along a new leading 'layers' axis
    (the lax.scan parameter layout)."""

    def leaf(d: ParamDef) -> ParamDef:
        return ParamDef(
            shape=(n,) + d.shape,
            axes=(axis_name,) + d.axes,
            init=d.init,
            scale=d.scale,
            dtype=d.dtype,
        )

    return jax.tree.map(leaf, defs, is_leaf=_is_def)


def count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))
