"""State-space / linear-recurrence blocks: Mamba2 (SSD) and RWKV6 (Finch).

Both provide a chunked parallel form for training/prefill (O(T·Q) with chunk
size Q, MXU-friendly intra-chunk matmuls + a short lax.scan over chunks) and a
recurrent form for decode (state carried in the cache). These are the
sub-quadratic paths that make the ``long_500k`` shape runnable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param_defs import ParamDef
from repro.models.layers import init_rmsnorm, rms_norm

# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality chunked algorithm, arXiv:2405.21060)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Mamba2Spec:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(s: Mamba2Spec) -> Dict[str, Any]:
    di, ns, nh = s.d_inner, s.d_state, s.n_heads
    conv_dim = di + 2 * ns
    return {
        # order: [x (di), B (ns), C (ns), z (di), dt (nh)]
        "w_in": ParamDef((s.d_model, 2 * di + 2 * ns + nh), ("embed", "ffn")),
        "conv_w": ParamDef((s.d_conv, conv_dim), ("conv", None), scale=0.5),
        "conv_b": ParamDef((conv_dim,), (None,), init="zeros"),
        "A_log": ParamDef((nh,), (None,), init="zeros"),
        "D": ParamDef((nh,), (None,), init="ones"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "norm": init_rmsnorm(di),
        "w_out": ParamDef((di, s.d_model), ("ffn", "embed")),
    }


def _split_inproj(s: Mamba2Spec, zxbcdt: jax.Array):
    di, ns, nh = s.d_inner, s.d_state, s.n_heads
    x = zxbcdt[..., :di]
    Bm = zxbcdt[..., di : di + ns]
    Cm = zxbcdt[..., di + ns : di + 2 * ns]
    z = zxbcdt[..., di + 2 * ns : 2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns :]
    return x, Bm, Cm, z, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. xBC: (B,T,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD chunked scan.

    xh: (B,T,H,P) inputs; dt: (B,T,H) positive step sizes; A: (H,) negative
    decay rates; Bm/Cm: (B,T,N) input/output projections (single group).
    Returns (y: (B,T,H,P), final_state: (B,H,N,P)).
    """
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    if T % Q:
        # pad with dt=0 steps: decay 1, contribution 0 — state unaffected
        padn = Q - T % Q
        pad = lambda a: jnp.pad(a, ((0, 0), (0, padn)) + ((0, 0),) * (a.ndim - 2))
        y, final = ssd_chunked(pad(xh), pad(dt), A, pad(Bm), pad(Cm), chunk, init_state)
        return y[:, :T], final
    nc = T // Q

    xc = xh.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A  # (B,nc,Q,H) negative
    g = jnp.cumsum(dA, axis=2)  # cumulative log-decay within chunk
    # intra-chunk (quadratic within Q): att[i,j] = C_i·B_j * exp(g_i - g_j) * dt_j
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    # clamp at 0: entries with j > i are masked below, but exp overflow there
    # would still poison the BACKWARD pass (inf * 0 = nan in the vjp)
    decay = jnp.exp(jnp.minimum(g[:, :, :, None, :] - g[:, :, None, :, :], 0.0))
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    att = CB[..., None] * jnp.where(causal, decay, 0.0) * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(xh.dtype), xc)

    # chunk summary states: S_c = sum_j exp(g_last - g_j) dt_j B_j x_j^T
    last = g[:, :, -1:, :]  # (B,nc,1,H)
    w_j = jnp.exp(last - g) * dtc  # (B,nc,Q,H)
    S = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_j.astype(xh.dtype), Bc, xc)  # (B,nc,H,N,P)

    # inter-chunk recurrence over the nc chunk states
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

    def body(carry, inp):
        S_c, dec, S_new = inp
        out = carry  # state BEFORE this chunk
        nxt = out * dec[..., None, None] + S_new
        return nxt, out

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), xh.dtype)
    S_seq = jnp.moveaxis(S, 1, 0)  # (nc,B,H,N,P)
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)

    def scan_body(carry, inp):
        S_new, dec = inp
        prev = carry
        nxt = prev * dec[..., None, None].astype(xh.dtype) + S_new
        return nxt, prev

    final, prevs = jax.lax.scan(scan_body, init_state, (S_seq, dec_seq))
    S_prev = jnp.moveaxis(prevs, 0, 1)  # (B,nc,H,N,P) state entering each chunk

    # contribution of carried state: y_i += exp(g_i) C_i · S_prev
    y_inter = jnp.einsum(
        "bcih,bcin,bchnp->bcihp", jnp.exp(g).astype(xh.dtype), Cc, S_prev
    )
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y, final


def apply_mamba2(
    params, s: Mamba2Spec, x: jax.Array, init_state=None
) -> Tuple[jax.Array, jax.Array]:
    """Training / prefill. x: (B,T,D) -> (y, final_ssm_state)."""
    zxbcdt = jnp.einsum("btd,de->bte", x, params["w_in"])
    xi, Bm, Cm, z, dt = _split_inproj(s, zxbcdt)
    xBC = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xi, Bm, Cm = (
        xBC[..., : s.d_inner],
        xBC[..., s.d_inner : s.d_inner + s.d_state],
        xBC[..., s.d_inner + s.d_state :],
    )
    H, P = s.n_heads, s.head_dim
    xh = xi.reshape(*xi.shape[:2], H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, init_state)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], s.d_inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    return jnp.einsum("bte,ed->btd", y, params["w_out"]), final


def init_mamba2_cache(s: Mamba2Spec, batch: int, dtype=jnp.bfloat16):
    conv_dim = s.d_inner + 2 * s.d_state
    return {
        "conv": ParamDef((batch, s.d_conv - 1, conv_dim), ("batch", None, None), init="zeros", dtype=dtype),
        "ssm": ParamDef(
            (batch, s.n_heads, s.d_state, s.head_dim), ("batch", "heads", None, None), init="zeros", dtype=jnp.float32
        ),
    }


def decode_mamba2(params, s: Mamba2Spec, x, cache, pos):
    """One-token recurrent step. x: (B,1,D)."""
    zxbcdt = jnp.einsum("btd,de->bte", x, params["w_in"])
    xi, Bm, Cm, z, dt = _split_inproj(s, zxbcdt)
    xBC_new = jnp.concatenate([xi, Bm, Cm], axis=-1)  # (B,1,conv_dim)
    hist = jnp.concatenate([cache["conv"], xBC_new.astype(cache["conv"].dtype)], axis=1)
    # causal depthwise conv over the last d_conv inputs
    w = params["conv_w"]
    conv_out = sum(hist[:, i, :] * w[i] for i in range(s.d_conv)) + params["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]
    xi = xBC[..., : s.d_inner]
    Bm = xBC[..., s.d_inner : s.d_inner + s.d_state]
    Cm = xBC[..., s.d_inner + s.d_state :]
    H, P = s.n_heads, s.head_dim
    xh = xi.reshape(x.shape[0], H, P)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt1 * A)  # (B,H)
    S = cache["ssm"]
    S = S * dA[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", Bm[:, 0].astype(jnp.float32), xh.astype(jnp.float32), dt1
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), S).astype(x.dtype)
    y = y + params["D"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(x.shape[0], 1, s.d_inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bte,ed->btd", y, params["w_out"])
    return out, {"conv": new_conv, "ssm": S}


# ---------------------------------------------------------------------------
# RWKV6 ("Finch", arXiv:2404.05892) — data-dependent per-channel decay
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKV6Spec:
    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def init_rwkv6_time(s: RWKV6Spec) -> Dict[str, Any]:
    d = s.d_model
    return {
        # token-shift interpolation weights (static mu; the full 5-way lora of
        # Finch is approximated with per-stream static mixes + the decay lora,
        # which is the data-dependent part that defines RWKV6)
        "mu_r": ParamDef((d,), (None,), init="ones", scale=0.5),
        "mu_k": ParamDef((d,), (None,), init="ones", scale=0.5),
        "mu_v": ParamDef((d,), (None,), init="ones", scale=0.5),
        "mu_w": ParamDef((d,), (None,), init="ones", scale=0.5),
        "mu_g": ParamDef((d,), (None,), init="ones", scale=0.5),
        "wr": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wg": ParamDef((d, d), ("embed", "heads")),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x w1) w2))
        "w0": ParamDef((d,), (None,), init="zeros"),
        "w1": ParamDef((d, s.decay_lora), ("embed", None), scale=0.1),
        "w2": ParamDef((s.decay_lora, d), (None, "heads"), scale=0.1),
        "u": ParamDef((d,), (None,), init="zeros"),  # bonus for current token
        "ln_out": init_rmsnorm(d),
        "wo": ParamDef((d, d), ("heads", "embed")),
    }


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array] = None) -> jax.Array:
    """Previous-token stream; x_prev is the final token of the previous
    segment (decode) or zeros (training start)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu  # lerp toward the shifted stream


def rwkv6_chunked(r, k, v, logw, u, chunk: int, init_state=None):
    """Chunked RWKV6/GLA recurrence, scanned chunk-by-chunk.

    r,k,v: (B,T,H,K); logw: (B,T,H,K) negative log-decays (log w_t);
    u: (H,K) bonus for the current token. State S: (B,H,K,V). Convention:
        out_t = r_t·S_{t-1} + r_t·(u ⊙ k_t) v_t
        S_t   = diag(w_t)·S_{t-1} + k_t v_t^T
    The per-channel data-dependent decay makes the intra-chunk pair weights a
    (Q,Q,H,K) tensor; we keep it exact and bound memory by lax.scan over
    chunks (one chunk's pair tensor live at a time). This is the XLA
    reference path; the fused Pallas kernel (kernels/linear_scan) computes
    the same quantity tile-by-tile in VMEM.
    """
    B, T, H, K = r.shape
    Q = min(chunk, T)
    if T % Q:
        # pad with logw=0 (decay 1), k=v=0 — state unaffected
        padn = Q - T % Q
        pad = lambda a: jnp.pad(a, ((0, 0), (0, padn)) + ((0, 0),) * (a.ndim - 2))
        y, final = rwkv6_chunked(pad(r), pad(k), pad(v), pad(logw), u, chunk, init_state)
        return y[:, :T], final
    nc = T // Q
    V = v.shape[-1]

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, Q, H, -1), 1, 0)  # (nc,B,Q,H,·)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = to_chunks(logw.astype(jnp.float32))
    u32 = u.astype(jnp.float32)

    ii = jnp.arange(Q)
    strictly = (ii[:, None] > ii[None, :])[:, :, None, None]  # (Q,Q,1,1)

    if init_state is None:
        init_state = jnp.zeros((B, H, K, V), jnp.float32)

    def body(S, inp):
        rq, kq, vq, lwq = inp  # (B,Q,H,·)
        rq32, kq32, vq32 = rq.astype(jnp.float32), kq.astype(jnp.float32), vq.astype(jnp.float32)
        L = jnp.cumsum(lwq, axis=1)        # inclusive
        Lx = L - lwq                        # exclusive
        # intra-chunk pairwise decay (exact, bounded: diff <= 0 for j < i);
        # clamp so masked (j >= i) entries can't inf-poison the backward
        diff = jnp.minimum(Lx[:, :, None] - L[:, None, :], 0.0)  # (B,Q,Q,H,K)
        w_pair = jnp.where(strictly[None], jnp.exp(diff), 0.0)
        att = jnp.einsum("bihk,bijhk,bjhk->bhij", rq32, w_pair, kq32)
        y = jnp.einsum("bhij,bjhv->bihv", att, vq32)
        # bonus diagonal
        bon = jnp.einsum("bihk,hk,bihk->bih", rq32, u32, kq32)
        y = y + bon[..., None] * vq32
        # inter-chunk: carried state
        y = y + jnp.einsum("bihk,bhkv->bihv", rq32 * jnp.exp(Lx), S)
        # state update
        last = L[:, -1:, :, :]                          # (B,1,H,K)
        S_new = S * jnp.exp(last[:, 0])[..., None] + jnp.einsum(
            "bjhk,bjhv->bhkv", kq32 * jnp.exp(last - L), vq32
        )
        return S_new, y

    final, ys = jax.lax.scan(body, init_state, (rc, kc, vc, lw))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, V)
    return y, final


def apply_rwkv6_time(params, s: RWKV6Spec, x: jax.Array, init_state=None, x_prev=None):
    B, T, D = x.shape
    H, K = s.n_heads, s.head_dim
    xs = _token_shift(x, x_prev)
    xr = _mix(x, xs, params["mu_r"].astype(x.dtype))
    xk = _mix(x, xs, params["mu_k"].astype(x.dtype))
    xv = _mix(x, xs, params["mu_v"].astype(x.dtype))
    xw = _mix(x, xs, params["mu_w"].astype(x.dtype))
    xg = _mix(x, xs, params["mu_g"].astype(x.dtype))
    r = jnp.einsum("btd,de->bte", xr, params["wr"]).reshape(B, T, H, K)
    k = jnp.einsum("btd,de->bte", xk, params["wk"]).reshape(B, T, H, K)
    v = jnp.einsum("btd,de->bte", xv, params["wv"]).reshape(B, T, H, K)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, params["wg"]))
    # data-dependent decay (the defining RWKV6 feature)
    dd = jnp.einsum(
        "btl,le->bte", jnp.tanh(jnp.einsum("btd,dl->btl", xw, params["w1"])), params["w2"]
    )
    logw = -jnp.exp(
        jnp.clip(params["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -8.0, 4.0)
    ).reshape(B, T, H, K)
    u = params["u"].astype(jnp.float32).reshape(H, K)
    y, final = rwkv6_chunked(r, k, v, logw, u, s.chunk, init_state)
    y = y.reshape(B, T, D).astype(x.dtype) * g
    y = rms_norm(params["ln_out"], y)
    return jnp.einsum("btd,de->btd", y, params["wo"]), final, x[:, -1:]


def init_rwkv6_cache(s: RWKV6Spec, batch: int, dtype=jnp.bfloat16):
    return {
        "state": ParamDef(
            (batch, s.n_heads, s.head_dim, s.head_dim), ("batch", "heads", None, None), init="zeros", dtype=jnp.float32
        ),
        "x_prev": ParamDef((batch, 1, s.d_model), ("batch", None, None), init="zeros", dtype=dtype),
        "x_prev_ffn": ParamDef((batch, 1, s.d_model), ("batch", None, None), init="zeros", dtype=dtype),
    }


def decode_rwkv6_time(params, s: RWKV6Spec, x, state, x_prev):
    """One token. x: (B,1,D); state: (B,H,K,V); x_prev: (B,1,D)."""
    B, _, D = x.shape
    H, K = s.n_heads, s.head_dim
    xs = x_prev
    xr = _mix(x, xs, params["mu_r"].astype(x.dtype))
    xk = _mix(x, xs, params["mu_k"].astype(x.dtype))
    xv = _mix(x, xs, params["mu_v"].astype(x.dtype))
    xw = _mix(x, xs, params["mu_w"].astype(x.dtype))
    xg = _mix(x, xs, params["mu_g"].astype(x.dtype))
    r = jnp.einsum("btd,de->bte", xr, params["wr"]).reshape(B, H, K)
    k = jnp.einsum("btd,de->bte", xk, params["wk"]).reshape(B, H, K)
    v = jnp.einsum("btd,de->bte", xv, params["wv"]).reshape(B, H, K)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, params["wg"]))
    dd = jnp.einsum("btl,le->bte", jnp.tanh(jnp.einsum("btd,dl->btl", xw, params["w1"])), params["w2"])
    w = jnp.exp(-jnp.exp(jnp.clip(params["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -8.0, 4.0)))
    w = w.reshape(B, H, K)
    u = params["u"].astype(jnp.float32).reshape(H, K)
    r32, k32, v32 = r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    out = jnp.einsum("bhk,bhkv->bhv", r32, state) + jnp.einsum(
        "bhk,hk,bhk,bhv->bhv", r32, u, k32, v32
    )
    new_state = state * w[..., None] + jnp.einsum("bhk,bhv->bhkv", k32, v32)
    y = out.reshape(B, 1, D).astype(x.dtype) * g
    y = rms_norm(params["ln_out"], y)
    return jnp.einsum("btd,de->btd", y, params["wo"]), new_state, x


def init_rwkv6_channel(s: RWKV6Spec, d_ff: int) -> Dict[str, Any]:
    d = s.d_model
    return {
        "mu_k": ParamDef((d,), (None,), init="ones", scale=0.5),
        "mu_r": ParamDef((d,), (None,), init="ones", scale=0.5),
        "wk": ParamDef((d, d_ff), ("embed", "ffn")),
        "wv": ParamDef((d_ff, d), ("ffn", "embed")),
        "wr": ParamDef((d, d), ("embed", None)),
    }


def apply_rwkv6_channel(params, x: jax.Array, x_prev=None):
    xs = _token_shift(x, x_prev)
    xk = _mix(x, xs, params["mu_k"].astype(x.dtype))
    xr = _mix(x, xs, params["mu_r"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, params["wk"])))
    kv = jnp.einsum("btf,fd->btd", k, params["wv"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["wr"]))
    return r * kv, x[:, -1:]
