"""The paper's evaluation model: a 4-layer MLP, 785x500x100x10.

(785 = 784 pixels + bias, i.e. standard 784-in layers with biases.) Pure jax;
parameters flatten deterministically (sorted dict order) for the IPLS
partition plane.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LAYERS = [(784, 500), (500, 100), (100, 10)]


def init_params(seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for i, (fan_in, fan_out) in enumerate(LAYERS):
        bound = float(np.sqrt(6.0 / (fan_in + fan_out)))
        params[f"w{i}"] = rng.uniform(-bound, bound, size=(fan_in, fan_out)).astype(np.float32)
        params[f"b{i}"] = np.zeros((fan_out,), np.float32)
    return params


def apply(params, x: jax.Array) -> jax.Array:
    h = x
    n = len(LAYERS)
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def loss_and_acc(params, x: jax.Array, y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    logits = apply(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    acc = (jnp.argmax(logits, axis=1) == y).mean()
    return nll, acc


from functools import partial


@partial(jax.jit, static_argnums=(4,))
def sgd_steps(params, x, y, lr: float, num_iters: int):
    """Run ``num_iters`` SGD iterations on one (already-batched) shard chunk.
    The paper's local optimisation phase: plain SGD on local data."""

    def body(p, _):
        grads = jax.grad(lambda q: loss_and_acc(q, x, y)[0])(p)
        p = jax.tree.map(lambda w, g: w - lr * g, p, grads)
        return p, None

    params, _ = jax.lax.scan(body, params, None, length=num_iters)
    return params


@partial(jax.jit, static_argnums=(4, 5))
def sgd_steps_flat(w_flat, x, y, lr: float, num_iters: int, layout):
    """`sgd_steps` on the FLAT weight vector: the loss unflattens inside, so
    the gradient arrives flat (the slice/reshape transpose fuses into the
    backward) and callers never pay the tree->vector->tree round trips. The
    vectorized round engine vmaps this over all agents; `layout` is the
    (hashable) flatten layout from `core.partition.flatten_params`."""
    from repro.core.partition import unflatten_params

    def body(w, _):
        g = jax.grad(lambda q: loss_and_acc(unflatten_params(q, layout), x, y)[0])(w)
        return w - lr * g, None

    w2, _ = jax.lax.scan(body, w_flat, None, length=num_iters)
    return w2


@jax.jit
def evaluate(params, x, y) -> jax.Array:
    return loss_and_acc(params, x, y)[1]
