"""Composable decoder-only LM stack covering 8 of the 10 assigned archs.

A model is a sequence of GROUPS; each group is a PERIOD of heterogeneous
sub-blocks (attn / mla / mlp / moe / mamba2 / rwkv6) repeated ``repeat``
times via lax.scan with stacked parameters — HLO stays small (one period
body) and compile times stay sane at 80 layers. A group may also reference a
SHARED block (zamba2's shared attention) whose weights live outside the scan
and are closed over as scan constants, while its per-occurrence KV cache is
stacked like everything else.

Three execution modes per block:
    train:   x -> y                      (no cache; remat-able scan body)
    prefill: x -> (y, cache_entry)       (builds the serving cache)
    decode:  (x, cache_entry, pos) -> (y, new_cache_entry)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.param_defs import ParamDef, axes_tree, init_tree, shape_tree, stack_defs, count_params
from repro.models.sharding_hooks import shard_act


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str                                   # attn|mla|mlp|moe|mamba2|rwkv6_time|rwkv6_channel
    attn: Optional[L.AttnSpec] = None
    mla: Optional[L.MLASpec] = None
    mlp: Optional[L.MLPSpec] = None
    moe: Optional[L.MoESpec] = None
    mamba: Optional[S.Mamba2Spec] = None
    rwkv: Optional[S.RWKV6Spec] = None
    rwkv_ffn: int = 0
    norm: str = "rms"                            # rms | ln


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    blocks: Tuple[BlockSpec, ...]
    repeat: int = 1
    shared: Tuple[BlockSpec, ...] = ()           # applied after blocks, weights shared


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    vocab: int
    d_model: int
    groups: Tuple[GroupSpec, ...]
    tie_embeddings: bool = False
    embed_scale: bool = False                    # gemma: x *= sqrt(d_model)
    final_norm: str = "rms"
    subquadratic: bool = False                   # eligible for long_500k
    mrope: bool = False                          # expects positions3 input
    lb_loss_weight: float = 0.01
    remat: bool = True
    logit_softcap: Optional[float] = None
    # per-arch logical->mesh rule overrides (e.g. granite expert sharding)
    sharding_overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_layers(self) -> int:
        return sum(g.repeat * len(g.blocks) for g in self.groups)


# ---------------------------------------------------------------------------
# block init / apply dispatch
# ---------------------------------------------------------------------------


def _sharded_ce(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token NLL that stays local under vocab sharding.

    take_along_axis (gather) over a sharded vocab dim forces GSPMD to
    all-gather the full (B,S,V) logits — measured at 333 GB/device wire on
    minitron-4b train_4k. The masked-reduction form fuses into the softmax
    loops and lowers to local partial reductions + an (B,S)-sized psum.
    """
    V = logits.shape[-1]
    l32 = logits.astype(jnp.float32)
    m = jnp.max(l32, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(l32 - m[..., None]), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    tgt = jnp.sum(jnp.where(vocab_iota == targets[..., None], l32, 0.0), axis=-1)
    return lse - tgt


def _norm_init(kind: str, d: int):
    return L.init_rmsnorm(d) if kind == "rms" else L.init_layernorm(d)


def _norm_apply(kind: str, p, x):
    return L.rms_norm(p, x) if kind == "rms" else L.layer_norm(p, x)


def block_defs(b: BlockSpec, d_model: int) -> Dict[str, Any]:
    defs: Dict[str, Any] = {"norm": _norm_init(b.norm, d_model)}
    if b.kind == "attn":
        defs["attn"] = L.init_attention(b.attn)
    elif b.kind == "mla":
        defs["mla"] = L.init_mla(b.mla)
    elif b.kind == "mlp":
        defs["mlp"] = L.init_mlp(b.mlp)
    elif b.kind == "moe":
        defs["moe"] = L.init_moe(b.moe)
    elif b.kind == "mamba2":
        defs["mamba"] = S.init_mamba2(b.mamba)
    elif b.kind == "rwkv6_time":
        defs["rwkv"] = S.init_rwkv6_time(b.rwkv)
    elif b.kind == "rwkv6_channel":
        defs["rwkv_ffn"] = S.init_rwkv6_channel(b.rwkv, b.rwkv_ffn)
    else:
        raise ValueError(b.kind)
    return defs


def block_cache_defs(b: BlockSpec, batch: int, seq_len: int) -> Optional[Dict[str, Any]]:
    if b.kind == "attn":
        return L.init_attn_cache(b.attn, batch, seq_len)
    if b.kind == "mla":
        return L.init_mla_cache(b.mla, batch, seq_len)
    if b.kind == "mamba2":
        return S.init_mamba2_cache(b.mamba, batch)
    if b.kind == "rwkv6_time":
        return {
            "state": ParamDef((batch, b.rwkv.n_heads, b.rwkv.head_dim, b.rwkv.head_dim),
                              ("batch", "heads", None, None), init="zeros", dtype=jnp.float32),
            "x_prev": ParamDef((batch, 1, b.rwkv.d_model), ("batch", None, None), init="zeros"),
        }
    if b.kind == "rwkv6_channel":
        return {
            "x_prev": ParamDef((batch, 1, b.rwkv.d_model), ("batch", None, None), init="zeros"),
        }
    return None  # mlp / moe are stateless


def _gatherable(b: BlockSpec) -> bool:
    """Megatron-SP full-seq gather is profitable only when the block's
    parallel dim divides the model axis; otherwise the block's weights are
    replicated and gathering the input would REPLICATE its compute
    (measured: minitron's 24 heads over 16 chips -> 2.1x total flops)."""
    from repro.models.sharding_hooks import act_mesh_axis_size

    m = act_mesh_axis_size("model")
    if m == 1:
        return False
    if b.kind in ("mlp",):
        return b.mlp.d_ff % m == 0
    if b.kind in ("moe",):
        return True  # dispatch path is shard_mapped; shared expert ffn-sharded
    if b.kind == "attn":
        return b.attn.n_heads % m == 0
    if b.kind == "mla":
        return b.mla.n_heads % m == 0
    if b.kind in ("mamba2", "rwkv6_time", "rwkv6_channel"):
        return True  # recurrent over time: needs the full sequence anyway
    return False


def apply_block_train(b: BlockSpec, p, x, ctx) -> Tuple[jax.Array, jax.Array]:
    """Returns (y_residual_added, aux_scalar)."""
    h = _norm_apply(b.norm, p["norm"], x)
    if _gatherable(b):
        # Megatron-SP style: ONE bf16 all-gather of the block input over the
        # sequence axis (the residual stream is sequence-parallel between
        # blocks); without this, GSPMD gathers q/k/v separately — measured
        # 1.7x more wire, and in f32 when the gather sinks into rope.
        h = shard_act(h, ("batch", None, "embed"))
    aux = jnp.zeros((), jnp.float32)
    if b.kind == "attn":
        y = L.apply_attention(
            p["attn"], b.attn, h,
            ctx["positions3"] if b.attn.rope == "mrope" else ctx["positions"],
            seq_parallel=not _gatherable(b),
        )
    elif b.kind == "mla":
        y = L.apply_mla(p["mla"], b.mla, h, ctx["positions"])
    elif b.kind == "mlp":
        y = L.apply_mlp(p["mlp"], b.mlp, h)
    elif b.kind == "moe":
        y, moe_aux = L.apply_moe(p["moe"], b.moe, h)
        aux = moe_aux["lb_loss"]
    elif b.kind == "mamba2":
        y, _ = S.apply_mamba2(p["mamba"], b.mamba, h)
    elif b.kind == "rwkv6_time":
        y, _, _ = S.apply_rwkv6_time(p["rwkv"], b.rwkv, h)
    elif b.kind == "rwkv6_channel":
        y, _ = S.apply_rwkv6_channel(p["rwkv_ffn"], h)
    else:
        raise ValueError(b.kind)
    x = x + y
    x = shard_act(x, ("batch", "act_seq", "embed"))
    return x, aux


def apply_block_prefill(b: BlockSpec, p, x, ctx):
    """Returns (y, cache_entry)."""
    h = _norm_apply(b.norm, p["norm"], x)
    cache = None
    if b.kind == "attn":
        s = b.attn
        pos = ctx["positions3"] if s.rope == "mrope" else ctx["positions"]
        q, k, v = L._proj_qkv(p["attn"], s, h)
        q, k = L._rope_qk(s, q, k, pos)
        Sq = h.shape[1]
        mask = L.causal_mask(Sq, Sq, s.window) if s.causal else None
        out = L._sdpa(q, k, v, mask, s.n_heads // s.kv_heads)
        y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
        T = min(ctx["cache_len"], s.window) if s.window is not None else ctx["cache_len"]
        kc = jnp.zeros((k.shape[0], T) + k.shape[2:], k.dtype)
        vc = jnp.zeros_like(kc)
        keep = min(T, Sq)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k[:, -keep:], 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v[:, -keep:], 0, axis=1)
        # repro: noqa[JX02] T derives from ctx["cache_len"], a host int
        # threaded through the ctx dict; only the positions entries trace
        if s.window is not None and keep == T:
            # ring-buffer alignment: token at absolute position p lives at
            # slot p % T, matching decode's slot = pos % T
            shift = Sq % T
            kc = jnp.roll(kc, shift, axis=1)
            vc = jnp.roll(vc, shift, axis=1)
        cache = {"k": kc, "v": vc}
    elif b.kind == "mla":
        s = b.mla
        y = L.apply_mla(p["mla"], s, h, ctx["positions"])
        latent = L.rms_norm(p["mla"]["kv_norm"], jnp.einsum("bsd,dl->bsl", h, p["mla"]["wdkv"]))
        k_rope = L.apply_rope(
            jnp.einsum("bsd,dk->bsk", h, p["mla"]["wk_rope"])[:, :, None, :], ctx["positions"], s.rope_theta
        )[:, :, 0, :]
        T = ctx["cache_len"]
        lat = jnp.zeros((latent.shape[0], T, latent.shape[-1]), latent.dtype)
        kr = jnp.zeros((k_rope.shape[0], T, k_rope.shape[-1]), k_rope.dtype)
        keep = min(T, latent.shape[1])
        lat = jax.lax.dynamic_update_slice_in_dim(lat, latent[:, -keep:], 0, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(kr, k_rope[:, -keep:], 0, axis=1)
        cache = {"latent": lat, "k_rope": kr}
    elif b.kind == "mlp":
        y = L.apply_mlp(p["mlp"], b.mlp, h)
    elif b.kind == "moe":
        y, _ = L.apply_moe(p["moe"], b.moe, h)
    elif b.kind == "mamba2":
        y, final = S.apply_mamba2(p["mamba"], b.mamba, h)
        # conv tail: the last (d_conv-1) pre-conv inputs
        zxbcdt = jnp.einsum("btd,de->bte", h, p["mamba"]["w_in"])
        xi, Bm, Cm, _, _ = S._split_inproj(b.mamba, zxbcdt)
        xBC = jnp.concatenate([xi, Bm, Cm], axis=-1)
        cache = {"conv": xBC[:, -(b.mamba.d_conv - 1) :, :], "ssm": final.astype(jnp.float32)}
        y = y  # already projected
    elif b.kind == "rwkv6_time":
        y, final, x_last = S.apply_rwkv6_time(p["rwkv"], b.rwkv, h)
        cache = {"state": final, "x_prev": x_last}
    elif b.kind == "rwkv6_channel":
        y, x_last = S.apply_rwkv6_channel(p["rwkv_ffn"], h)
        cache = {"x_prev": x_last}
    else:
        raise ValueError(b.kind)
    return x + y, cache


def apply_block_decode(b: BlockSpec, p, x, cache, pos, ctx):
    h = _norm_apply(b.norm, p["norm"], x)
    if b.kind == "attn":
        y, new_cache = L.decode_attention(p["attn"], b.attn, h, cache, pos)
    elif b.kind == "mla":
        y, new_cache = L.decode_mla(p["mla"], b.mla, h, cache, pos)
    elif b.kind == "mlp":
        return x + L.apply_mlp(p["mlp"], b.mlp, h), cache
    elif b.kind == "moe":
        y, _ = L.apply_moe(p["moe"], b.moe, h)
        return x + y, cache
    elif b.kind == "mamba2":
        y, new_cache = S.decode_mamba2(p["mamba"], b.mamba, h, cache, pos)
    elif b.kind == "rwkv6_time":
        y, new_state, x_last = S.decode_rwkv6_time(p["rwkv"], b.rwkv, h, cache["state"], cache["x_prev"])
        new_cache = {"state": new_state, "x_prev": x_last.astype(cache["x_prev"].dtype)}
    elif b.kind == "rwkv6_channel":
        y, x_last = S.apply_rwkv6_channel(p["rwkv_ffn"], h, cache["x_prev"])
        new_cache = {"x_prev": x_last.astype(cache["x_prev"].dtype)}
    else:
        raise ValueError(b.kind)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class TransformerLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- parameter plane ----------------------------------------------------
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {"embed": L.init_embedding(cfg.vocab, cfg.d_model)}
        for gi, g in enumerate(cfg.groups):
            period = {f"b{bi}": block_defs(b, cfg.d_model) for bi, b in enumerate(g.blocks)}
            defs[f"g{gi}"] = stack_defs(period, g.repeat)
            if g.shared:
                defs[f"g{gi}_shared"] = {
                    f"b{bi}": block_defs(b, cfg.d_model) for bi, b in enumerate(g.shared)
                }
        defs["final_norm"] = _norm_init(cfg.final_norm, cfg.d_model)
        if not cfg.tie_embeddings:
            defs["lm_head"] = {
                "table": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02)
            }
        return defs

    def init(self, seed: int = 0):
        return init_tree(self.param_defs(), jax.random.PRNGKey(seed))

    def axes(self):
        return axes_tree(self.param_defs())

    def param_shapes(self):
        return shape_tree(self.param_defs())

    def num_params(self) -> int:
        return count_params(jax.tree.leaves(self.param_shapes()))

    # active (per-token) params, for MODEL_FLOPS = 6 * N_active * D.
    # MoE experts count as top_k/num_experts of their weights; shared blocks
    # count once per application (i.e. ``repeat`` times); embedding/unembed
    # excluded (gather, not matmul) but the LM head matmul included.
    def num_active_params(self) -> int:
        from repro.models.param_defs import count_params as _cp

        def block_active(b: BlockSpec) -> int:
            defs = block_defs(b, self.cfg.d_model)
            n = _cp(shape_tree(defs))
            if b.kind == "moe":
                expert_n = _cp(shape_tree({k: defs["moe"][k] for k in ("wg", "wu", "wd")}))
                n = n - expert_n + expert_n * b.moe.top_k // b.moe.num_experts
            return n

        total = 0
        for g in self.cfg.groups:
            per_period = sum(block_active(b) for b in g.blocks)
            per_period += sum(block_active(b) for b in g.shared)
            total += per_period * g.repeat
        total += self.cfg.vocab * self.cfg.d_model  # unembed matmul
        return total

    # -- context --------------------------------------------------------------
    def _ctx(self, batch: Dict[str, jax.Array], cache_len: int = 0) -> Dict[str, Any]:
        tokens = batch["tokens"]
        B, Sq = tokens.shape[0], tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
        ctx = {"positions": positions, "cache_len": cache_len}
        if self.cfg.mrope:
            p3 = batch.get("positions3")
            if p3 is None:
                p3 = jnp.broadcast_to(positions[None], (3, B, Sq))
            ctx["positions3"] = p3
        return ctx

    # -- forward (training) ---------------------------------------------------
    def _stack_apply_train(self, params, x, ctx):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        for gi, g in enumerate(cfg.groups):
            gp = params[f"g{gi}"]
            shared_p = params.get(f"g{gi}_shared")

            def body(carry, p_slice, g=g, shared_p=shared_p):
                x, aux = carry
                for bi, b in enumerate(g.blocks):
                    x, a = apply_block_train(b, p_slice[f"b{bi}"], x, ctx)
                    aux = aux + a
                for bi, b in enumerate(g.shared):
                    x, a = apply_block_train(b, shared_p[f"b{bi}"], x, ctx)
                    aux = aux + a
                return (x, aux), None

            if cfg.remat:
                body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), gp)
        return x, aux_total

    def _embed_in(self, params, tokens):
        x = L.embed(params["embed"], tokens)
        if self.cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        return shard_act(x, ("batch", "act_seq", "embed"))

    def _logits(self, params, x):
        """bf16 logits with f32 MXU accumulation — at vocab 262k the (B,S,V)
        tensor is the biggest activation in the model; keeping it bf16 and
        sharding V over "model" is what makes the large-vocab archs fit."""
        table = params["embed"]["table"] if self.cfg.tie_embeddings else params["lm_head"]["table"]
        logits = jnp.einsum(
            "bsd,vd->bsv", x, table, preferred_element_type=jnp.float32
        ).astype(jnp.bfloat16)
        if self.cfg.logit_softcap:
            c = self.cfg.logit_softcap
            logits = (jnp.tanh(logits.astype(jnp.float32) / c) * c).astype(jnp.bfloat16)
        return shard_act(logits, ("batch", None, "vocab"))

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token CE. batch: tokens (B,S) [+ positions3]. Returns
        (per_example_loss (B,), aux). CE via streaming max/logsumexp over the
        bf16 logits (no f32 (B,S,V) materialization)."""
        tokens = batch["tokens"]
        ctx = self._ctx(batch)
        x = self._embed_in(params, tokens)
        x, aux_lb = self._stack_apply_train(params, x, ctx)
        x = _norm_apply(self.cfg.final_norm, params["final_norm"], x)
        # un-shard the sequence BEFORE the unembed: otherwise the dtable
        # backward contraction (V-sharded dlogits x seq-sharded x) makes
        # GSPMD all-gather the full f32 logits (measured 128 GB wire on
        # minitron); gathering x here is 805 MB bf16 instead.
        x = shard_act(x, ("batch", None, "embed"))
        logits = self._logits(params, x[:, :-1])
        targets = tokens[:, 1:].astype(jnp.int32)
        nll = _sharded_ce(logits, targets)
        per_ex = jnp.mean(nll, axis=-1) + self.cfg.lb_loss_weight * aux_lb / max(self.cfg.n_layers, 1)
        return per_ex, {"lb_loss": aux_lb}

    # -- serving ---------------------------------------------------------------
    def cache_defs(self, batch: int, cache_len: int) -> Dict[str, Any]:
        defs: Dict[str, Any] = {}
        for gi, g in enumerate(self.cfg.groups):
            period: Dict[str, Any] = {}
            for bi, b in enumerate(g.blocks):
                cd = block_cache_defs(b, batch, cache_len)
                if cd is not None:
                    period[f"b{bi}"] = cd
            for bi, b in enumerate(g.shared):
                cd = block_cache_defs(b, batch, cache_len)
                if cd is not None:
                    period[f"s{bi}"] = cd
            if period:
                defs[f"g{gi}"] = stack_defs(period, g.repeat)
        return defs

    def init_cache(self, batch: int, cache_len: int):
        return init_tree(self.cache_defs(batch, cache_len), jax.random.PRNGKey(0))

    def cache_axes(self, batch: int, cache_len: int):
        return axes_tree(self.cache_defs(batch, cache_len))

    def prefill(self, params, batch):
        """Full-prompt forward; returns (last_token_logits, cache)."""
        tokens = batch["tokens"]
        cache_len = batch.get("cache_len", tokens.shape[1])
        ctx = self._ctx(batch, cache_len=cache_len)
        x = self._embed_in(params, tokens)
        caches: Dict[str, Any] = {}
        for gi, g in enumerate(self.cfg.groups):
            gp = params[f"g{gi}"]
            shared_p = params.get(f"g{gi}_shared")

            def body(x, p_slice, g=g, shared_p=shared_p):
                entries: Dict[str, Any] = {}
                for bi, b in enumerate(g.blocks):
                    x, c = apply_block_prefill(b, p_slice[f"b{bi}"], x, ctx)
                    if c is not None:
                        entries[f"b{bi}"] = c
                for bi, b in enumerate(g.shared):
                    x, c = apply_block_prefill(b, shared_p[f"b{bi}"], x, ctx)
                    if c is not None:
                        entries[f"s{bi}"] = c
                return x, entries

            x, stacked = jax.lax.scan(body, x, gp)
            if stacked:
                caches[f"g{gi}"] = stacked
        x = _norm_apply(self.cfg.final_norm, params["final_norm"], x)
        logits = self._logits(params, x[:, -1:])
        return logits, caches

    def decode_step(self, params, cache, batch):
        """One new token. batch: token (B,1), pos () int32."""
        token, pos = batch["token"], batch["pos"]
        ctx = self._ctx({"tokens": token})
        x = self._embed_in(params, token)
        new_caches: Dict[str, Any] = {}
        for gi, g in enumerate(self.cfg.groups):
            gp = params[f"g{gi}"]
            shared_p = params.get(f"g{gi}_shared")
            gc = cache.get(f"g{gi}")

            def body(x, slices, g=g, shared_p=shared_p):
                p_slice, c_slice = slices
                new_entries: Dict[str, Any] = {}
                for bi, b in enumerate(g.blocks):
                    key = f"b{bi}"
                    if key in c_slice:
                        x, nc = apply_block_decode(b, p_slice[key], x, c_slice[key], pos, ctx)
                        new_entries[key] = nc
                    else:
                        x, nc = apply_block_decode(b, p_slice[key], x, None, pos, ctx)
                for bi, b in enumerate(g.shared):
                    key = f"s{bi}"
                    x, nc = apply_block_decode(b, shared_p[f"b{bi}"], x, c_slice.get(key), pos, ctx)
                    if key in c_slice:
                        new_entries[key] = nc
                return x, new_entries

            if gc is not None:
                x, new_gc = jax.lax.scan(body, x, (gp, gc))
                new_caches[f"g{gi}"] = new_gc
            else:
                def body_nc(x, p_slice, g=g, shared_p=shared_p):
                    for bi, b in enumerate(g.blocks):
                        x, _ = apply_block_decode(b, p_slice[f"b{bi}"], x, None, pos, ctx)
                    return x, None
                x, _ = jax.lax.scan(body_nc, x, gp)
        x = _norm_apply(self.cfg.final_norm, params["final_norm"], x)
        logits = self._logits(params, x)
        return logits, new_caches
