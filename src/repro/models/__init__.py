from repro.models import mlp_mnist

__all__ = ["mlp_mnist"]
