"""Activation-sharding hook.

Models call ``shard_act(x, ("batch", "act_seq", "embed"))`` at block
boundaries. Outside a mesh context this is the identity (CPU smoke tests);
inside the launcher's context it applies with_sharding_constraint using the
same logical→mesh rules as the parameter plane — this is how sequence
parallelism and context-parallel KV sharding are expressed.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.core.sharded import DEFAULT_RULES, spec_for_leaf

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_sharding_ctx", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Optional[dict] = None):
    merged = dict(DEFAULT_RULES, **(rules or {}))
    token = _CTX.set((mesh, merged))
    try:
        yield
    finally:
        _CTX.reset(token)


def shard_act(x: jax.Array, names: tuple) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for_leaf(tuple(names), tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def act_mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active sharding context (1 if none)."""
    ctx = _CTX.get()
    if ctx is None:
        return 1
    mesh, _ = ctx
    return int(mesh.shape[name]) if name in mesh.axis_names else 1
