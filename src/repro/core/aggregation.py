"""IPLS aggregation math (paper §2.2, UpdateModel).

A responsible agent receives, for its partition k, deltas ``delta_k`` from r
contributing agents. It applies

    w_k <- w_k - eps * mean_contrib(delta_k)
    eps <- alpha * eps + (1 - alpha) * (1 / r)

``eps`` is the paper's staleness/confidence weight: with stable, full
participation (r constant) eps converges to (1-alpha)/ ... -> 1/r-weighted
step; with dropouts r shrinks and eps adapts. The paper leaves the exact
reduction of the r deltas unstated beyond "exchange the newly calculated
values ... to calculate the new global parameters"; we use the masked mean
(FedAvg reduction), the natural choice that makes IPLS == centralized FedAvg
under perfect connectivity. That equivalence is property-tested.

All functions are pure jax and jit-safe; contribution masks make them usable
under lax control flow and under shard_map (see core/sharded.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EpsState(NamedTuple):
    """Per-partition staleness weight state."""

    eps: jax.Array  # scalar or per-partition vector
    alpha: jax.Array  # scalar smoothing in (0, 1)


def init_eps(alpha: float = 0.5, shape=()) -> EpsState:
    return EpsState(eps=jnp.ones(shape, jnp.float32), alpha=jnp.asarray(alpha, jnp.float32))


def update_eps(state: EpsState, r: jax.Array) -> EpsState:
    """eps <- alpha*eps + (1-alpha)*(1/r); r==0 keeps eps unchanged."""
    r = jnp.asarray(r, jnp.float32)
    safe_r = jnp.maximum(r, 1.0)
    new = state.alpha * state.eps + (1.0 - state.alpha) / safe_r
    eps = jnp.where(r > 0, new, state.eps)
    return EpsState(eps=eps, alpha=state.alpha)


def masked_mean(deltas: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean of ``deltas`` over axis 0 counting only rows with mask==1.

    deltas: (A, ...) one delta per (potential) contributor.
    mask:   (A,) 1.0 where the contribution arrived this round.
    Rows with mask==0 contribute nothing; if nobody contributed the result is 0.
    """
    mask = mask.astype(deltas.dtype)
    r = jnp.sum(mask)
    total = jnp.einsum("a,a...->...", mask, deltas)
    return jnp.where(r > 0, total / jnp.maximum(r, 1.0), jnp.zeros_like(total))


def aggregate_partition(
    w_k: jax.Array,
    deltas: jax.Array,
    mask: jax.Array,
    eps_state: EpsState,
) -> tuple[jax.Array, EpsState]:
    """One IPLS aggregation step for a single partition.

    Returns the new partition value and the updated eps state. Matches the
    paper: subtract the (masked-mean) delta scaled by eps, then update eps
    from the contributor count r.
    """
    r = jnp.sum(mask.astype(jnp.float32))
    agg = masked_mean(deltas, mask)
    new_w = w_k - eps_state.eps * agg
    return new_w, update_eps(eps_state, r)


def replica_consensus(values: jax.Array, weights: jax.Array | None = None) -> jax.Array:
    """Merge rho replica copies of a partition into one value.

    Replicas may diverge under asynchrony (paper Fig 3a: higher rho -> higher
    variance). Consensus = (weighted) mean; weights default to uniform.
    values: (rho, ...).
    """
    if weights is None:
        return jnp.mean(values, axis=0)
    weights = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    return jnp.einsum("r,r...->...", weights, values)


def apply_staleness_decay(delta: jax.Array, age_rounds: jax.Array, beta: float = 0.5) -> jax.Array:
    """Down-weight a late-arriving delta by beta**age (beyond-paper: the paper
    notes messages 'may be delivered after the start of the next training
    iteration'; this implements the standard staleness discount used when we
    do apply them)."""
    return delta * jnp.power(jnp.asarray(beta, delta.dtype), age_rounds.astype(delta.dtype))
