"""IPLS on a TPU mesh: the paper's protocol expressed as GSPMD shardings.

Mapping (see DESIGN.md §2):

  agent                    = a data-parallel rank (mesh axis "data")
  partition w_k            = the 1/|data| shard of each parameter leaf
  UpdateModel (send delta) = reduce-scatter of grads over "data"
  responsible-agent update = optimizer update on the owned shard only
                             (optimizer state sharded over "data" = ZeRO-1)
  LoadModel (fetch parts)  = all-gather of updated params over "data"
  replication rho          = the "pod" mesh axis: each pod holds a replica of
                             every partition; replica consensus = all-reduce
                             of aggregated updates across "pod"
  lightweight storage      = FSDP mode: params *stored* sharded over "data",
                             gathered per-layer on demand inside the scan
  staleness weight eps     = first-class: w <- w - eps * update,
                             eps <- alpha*eps + (1-alpha)/r, r = #participants

All of this is driven by logical-axis metadata: every parameter leaf carries a
tuple of logical axis names (one per dim); ``logical_to_mesh`` maps them to
mesh axes via rules; ZeRO-1/FSDP adds the "data" axis on the first free,
divisible dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.optimizers import Optimizer

# ---------------------------------------------------------------------------
# logical-axis sharding
# ---------------------------------------------------------------------------

# default rules: logical axis name -> mesh axis (None = replicate)
DEFAULT_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": None,          # d_model rows replicated; vocab cols sharded
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "ffn": "model",
    "experts": "model",     # expert dim sharded over model (expert parallel)
    "expert_ffn": None,
    "layers": None,          # stacked-scan leading axis
    "conv": None,
    "ssm": None,
    "batch": "data",
    "seq": None,
    "act_seq": "model",     # sequence-parallel residual stream between blocks
    "kv_seq": "model",      # context-parallel KV cache for decode
    "any": None,
}


def mesh_axis_size(mesh: Mesh, name) -> int:
    """Size of a mesh axis; supports tuples like ("pod", "data")."""
    if name is None:
        return 1
    if isinstance(name, tuple):
        size = 1
        for n in name:
            size *= mesh.shape[n]
        return size
    return mesh.shape[name]


def spec_for_leaf(
    axes: tuple[Optional[str], ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    rules: dict[str, Optional[str]],
    zero1_axis: Optional[str] = None,
) -> P:
    """Map a leaf's logical axes to a PartitionSpec.

    If ``zero1_axis`` is given (usually "data"), additionally shard the first
    dimension that (a) is unsharded after rule mapping and (b) is divisible by
    the mesh axis size. This implements the IPLS partition-ownership layout
    for grads / optimizer state / FSDP param storage.
    """
    assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
    mapped: list[Any] = []
    used_mesh_axes = set()

    def members(m):
        return m if isinstance(m, tuple) else (m,)

    for ax, dim in zip(axes, shape):
        m = rules.get(ax) if ax is not None else None
        if (
            m is not None
            and not (set(members(m)) & used_mesh_axes)
            and dim % mesh_axis_size(mesh, m) == 0
            and dim > 0
        ):
            mapped.append(m)
            used_mesh_axes.update(members(m))
        else:
            mapped.append(None)
    if zero1_axis is not None and zero1_axis not in used_mesh_axes:
        zsize = mesh_axis_size(mesh, zero1_axis)
        for i, (cur, dim) in enumerate(zip(mapped, shape)):
            if cur is None and dim % zsize == 0 and dim >= zsize:
                mapped[i] = zero1_axis
                break
            if cur is not None and dim % (mesh_axis_size(mesh, cur) * zsize) == 0:
                mapped[i] = tuple(members(cur)) + (zero1_axis,)
                break
    return P(*mapped)


def tree_shardings(
    axes_tree,
    shape_tree,
    mesh: Mesh,
    rules: Optional[dict[str, Optional[str]]] = None,
    zero1_axis: Optional[str] = None,
):
    """NamedSharding pytree for a params-like tree from its axes metadata."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def leaf(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else tuple(shp)
        return NamedSharding(mesh, spec_for_leaf(tuple(axes), tuple(shape), mesh, rules, zero1_axis))

    return jax.tree.map(leaf, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# train state
# ---------------------------------------------------------------------------


class IplsTrainState(NamedTuple):
    step: jax.Array          # ()
    params: Any              # pytree, compute layout
    opt_state: Any           # pytree, ZeRO-1 sharded over "data"
    eps: jax.Array           # () staleness weight (paper's epsilon)


@dataclasses.dataclass(frozen=True)
class IplsStepConfig:
    alpha: float = 0.5        # eps smoothing (paper)
    use_eps: bool = True      # False => plain data-parallel training (eps == 1)
    fsdp: bool = False        # store params sharded over "data" (IPLS storage)
    grad_clip: Optional[float] = 1.0
    accum_steps: int = 1      # microbatch accumulation


def init_state(params, optimizer: Optimizer) -> IplsTrainState:
    return IplsTrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=optimizer.init(params),
        eps=jnp.ones((), jnp.float32),
    )


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, Any]],
    optimizer: Optimizer,
    cfg: IplsStepConfig = IplsStepConfig(),
    num_agents: Optional[int] = None,
    update_shardings: Any = None,
):
    """Build the jittable IPLS train step.

    ``loss_fn(params, batch) -> (per_example_loss (B,), aux)``. The batch may
    contain ``participation``: a (B,) float mask, constant within each agent's
    (data rank's) sub-batch; dropped agents contribute nothing and r (the
    number of participants) feeds the eps update — exactly the paper's
    UpdateModel/aggregation semantics under churn.
    """

    def weighted_loss(params, batch):
        per_ex, aux = loss_fn(params, batch)
        mask = batch.get("participation")
        if mask is None:
            mask = jnp.ones_like(per_ex)
        mask = mask.astype(per_ex.dtype)
        total = jnp.sum(per_ex * mask)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return total / denom, (aux, jnp.sum(mask) / per_ex.shape[0])

    grad_fn = jax.value_and_grad(weighted_loss, has_aux=True)

    def one_microbatch(params, mb):
        (loss, (aux, frac)), grads = grad_fn(params, mb)
        return loss, aux, frac, grads

    def train_step(state: IplsTrainState, batch):
        params = state.params
        if cfg.accum_steps > 1:
            # split batch on leading dim into microbatches and accumulate
            def mb_slice(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // cfg.accum_steps), x.shape[0] // cfg.accum_steps, 0
                    ),
                    batch,
                )

            def body(carry, i):
                acc_loss, acc_frac, acc_grads = carry
                loss, _aux, frac, grads = one_microbatch(params, mb_slice(i))
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_loss + loss, acc_frac + frac, acc_grads), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, frac, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros(()), zeros), jnp.arange(cfg.accum_steps)
            )
            loss = loss / cfg.accum_steps
            frac = frac / cfg.accum_steps
            grads = jax.tree.map(lambda g: g / cfg.accum_steps, grads)
        else:
            loss, _aux, frac, grads = one_microbatch(params, batch)

        if cfg.grad_clip is not None:
            from repro.optim.optimizers import clip_by_global_norm

            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        else:
            from repro.optim.optimizers import global_norm

            gnorm = global_norm(grads)

        # --- the IPLS aggregation plane -----------------------------------
        # grads arrive here as the masked mean over participants ("the
        # responsible agent aggregates the deltas"); the sharding constraints
        # applied by the launcher force this to lower to reduce-scatter over
        # "data" (+ all-reduce over "pod" for replica consensus).
        updates, new_opt = optimizer.update(grads, state.opt_state, params, state.step)

        if cfg.use_eps:
            # paper semantics: eps tracks 1/r and weights the SUM of the r
            # contributions; our grads are already the masked MEAN, so the
            # applied scale is eps*r (steady state 1.0 == FedAvg; under churn
            # eps lags r and conservatively damps the post-churn step).
            n = num_agents if num_agents is not None else 1
            r = jnp.maximum(frac * n, 1.0)
            new_eps = cfg.alpha * state.eps + (1.0 - cfg.alpha) / r
            eps = new_eps * r
        else:
            eps = jnp.ones((), jnp.float32)
            new_eps = state.eps

        # responsible-agent update on the OWNED shard only, then LoadModel
        # all-gather of the bf16 result. Constraining the subtract to the
        # ZeRO-1 layout moves the all-gather AFTER the f32->bf16 cast —
        # measured 2x wire reduction vs XLA's default (gathering f32 updates).
        def apply_leaf(p, u, sh=None):
            p32 = p.astype(jnp.float32)
            if sh is not None:
                p32 = jax.lax.with_sharding_constraint(p32, sh)
                u = jax.lax.with_sharding_constraint(u, sh)
            return (p32 - eps * u).astype(p.dtype)

        if update_shardings is not None:
            new_params = jax.tree.map(apply_leaf, params, updates, update_shardings)
        else:
            new_params = jax.tree.map(apply_leaf, params, updates)

        new_state = IplsTrainState(
            step=state.step + 1, params=new_params, opt_state=new_opt, eps=new_eps
        )
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "participation": frac,
            "eps": new_eps,
        }
        return new_state, metrics

    return train_step


def state_shardings(
    axes_tree,
    params_shapes,
    optimizer: Optimizer,
    mesh: Mesh,
    rules: Optional[dict[str, Optional[str]]] = None,
    fsdp: bool = False,
):
    """Shardings for the full IplsTrainState.

    params: compute layout (TP over "model"; + "data" when fsdp=True);
    opt_state: ZeRO-1 — always + "data" (the IPLS partition-ownership);
    step/eps: replicated scalars.
    """
    param_sh = tree_shardings(axes_tree, params_shapes, mesh, rules, "data" if fsdp else None)
    # opt state mirrors params per leaf; Adam has (m, v) per leaf.
    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
    zero1_sh = tree_shardings(axes_tree, params_shapes, mesh, rules, "data")

    def opt_leaf_sharding(param_sharding_leaf, opt_leaf):
        return param_sharding_leaf

    # map each opt leaf to the zero1 sharding of its param (opt leaves have
    # identical shape to their param leaf; AdamLeaf is a NamedTuple of two)
    flat_params, treedef = jax.tree.flatten(params_shapes)
    flat_zero1 = treedef.flatten_up_to(zero1_sh)

    def build_opt_sh(opt_state_shapes):
        flat_opt, opt_def = jax.tree.flatten(opt_state_shapes)
        if not flat_opt:
            return opt_state_shapes  # e.g. SGD: empty state
        # group opt leaves by matching param leaf count
        n = len(flat_params)
        per = len(flat_opt) // max(n, 1)
        out = []
        for i, leaf in enumerate(flat_opt):
            out.append(flat_zero1[i // per] if per else flat_zero1[0])
        return jax.tree.unflatten(opt_def, out)

    scalar = NamedSharding(mesh, P())
    return IplsTrainState(
        step=scalar,
        params=param_sh,
        opt_state=build_opt_sh(opt_shapes),
        eps=scalar,
    )
