"""The IPLS middleware API (paper §2.2): Init, UpdateModel, LoadModel, Terminate.

Each ``IPLSAgent`` is the paper's middleware instance running on one device:
it owns a set of partitions (per the PartitionTable control plane), keeps the
authoritative values + eps state for those partitions, caches the latest
values of all other partitions (populated by UpdateModel replies), and talks
to peers through the (simulated) IPFS substrate.

The message protocol per training round:
  1. trainer computes local delta dW = W_local_before - W_local_after;
  2. UpdateModel(dW): slice dW by partition; for each partition pick a
     responsible agent (paper: 'many criteria ... such as locality, load';
     we use round-robin over holders keyed by (round, agent) for determinism)
     and send (partition_id, delta_slice); the holder replies with the updated
     global sub-vector, which lands in the cache;
  3. holders aggregate all deltas received for their partitions with the
     eps-weighted masked mean (core/aggregation.py) and, when rho > 1,
     exchange replica values on the partition topic and run replica consensus;
  4. LoadModel(): concatenate cache + owned values into the full W.

Serialization is numpy ``tobytes`` — the byte counts drive the scalability
benchmark (paper §3 'the data sent and received by each agent is constant').
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partition import PartitionSpec, PartitionTable
from repro.core.wire import F32Wire, Int8Wire, make_wire  # noqa: F401 (re-export)
from repro.p2p.ipfs_sim import SimIPFS

UPDATE_TOPIC = "ipls/update"
REPLY_TOPIC = "ipls/reply"
REPLICA_TOPIC = "ipls/replica"
MEMBER_TOPIC = "ipls/membership"
FETCH_TOPIC = "ipls/fetch"


@dataclasses.dataclass
class PartitionState:
    value: np.ndarray  # authoritative value of the owned partition
    eps: float = 1.0  # staleness weight (paper's epsilon)
    version: int = 0
    # dense message plane: contributor deltas land in a preallocated
    # (capacity, size) row buffer instead of a python list of arrays — the
    # buffer feeds the (batched) aggregation kernels directly and amortizes
    # all per-message allocations across rounds.
    pending: Optional[np.ndarray] = None
    pending_n: int = 0

    def push_delta(self, sl: np.ndarray) -> None:
        if self.pending is None:
            self.pending = np.empty((4, self.value.size), np.float32)
        elif self.pending_n == self.pending.shape[0]:
            grown = np.empty((2 * self.pending.shape[0], self.value.size), np.float32)
            grown[: self.pending_n] = self.pending
            self.pending = grown
        self.pending[self.pending_n] = sl
        self.pending_n += 1

    def drain_pending(self) -> Optional[np.ndarray]:
        """View of the r delta rows received this round (None when empty);
        resets the row count but keeps the allocation."""
        if self.pending_n == 0:
            return None
        rows = self.pending[: self.pending_n]
        self.pending_n = 0
        return rows


class IPLSAgent:
    """One agent's middleware. Control plane state is shared via ``table``
    (in a real deployment the table is replicated through pub/sub membership
    messages; the simulation shares the object and still sends the membership
    traffic for accounting)."""

    def __init__(
        self,
        agent_id: int,
        substrate: SimIPFS,
        table: PartitionTable,
        spec: PartitionSpec,
        alpha: float = 0.5,
        wire=None,
    ):
        self.id = agent_id
        self.net = substrate
        self.table = table
        self.spec = spec
        self.alpha = alpha
        self.wire = wire if wire is not None else F32Wire()
        self.owned: Dict[int, PartitionState] = {}
        self.cache: Dict[int, np.ndarray] = {}
        self._requesters: Dict[int, List[int]] = {}
        # error-feedback residual per partition this agent sends deltas FOR
        # (int8 wire only; residuals update at encode time, i.e. regardless
        # of whether the network later drops the message — deterministic and
        # loss-independent, which the vectorized scan carry mirrors)
        self._delta_err: Dict[int, np.ndarray] = {}
        self.live = True

    # -- Init --------------------------------------------------------------
    def init(self, w0: Optional[np.ndarray] = None) -> None:
        """Join the training process. First agent bootstraps with the full
        model w0; later agents acquire partitions per the join rule and fetch
        initial values from current holders (simulated via the store)."""
        for topic in (UPDATE_TOPIC, REPLY_TOPIC, REPLICA_TOPIC, MEMBER_TOPIC, FETCH_TOPIC):
            self.net.pubsub.subscribe(topic, self.id)
        offsets = self.spec.offsets()
        if not self.table.agents:
            assert w0 is not None, "bootstrap agent must supply initial weights"
            self.table.bootstrap(self.id)
            for k in self.table.partitions_of(self.id):
                sl = w0[offsets[k] : offsets[k] + self.spec.sizes[k]]
                self.owned[k] = PartitionState(value=sl.astype(np.float32).copy())
            for k in self.owned:
                self._subscribe_partition(k)
            # announce (init broadcast in the paper)
            self.net.pubsub.publish(
                MEMBER_TOPIC, self.id, ("init", self.id), nbytes=64
            )
            _AGENTS[self.id] = self
            return
        acquired = self.table.join(self.id)
        # fetch current values for acquired partitions. A partition may have
        # been TRANSFERRED (the donor is no longer in the table but still
        # holds the value) or REPLICATED (a current holder has it).
        for k in acquired:
            still_holding = set(self.table.holders_of(k))
            val, eps, ver, src = None, 1.0, 0, None
            for other_id in sorted(_AGENTS):
                other = _AGENTS[other_id]
                if other.id != self.id and k in other.owned:
                    val = other.owned[k].value.copy()
                    eps = other.owned[k].eps
                    # carry the version too: a replica restarting at 0 would
                    # trail the incumbents forever and merge_replicas would
                    # discard its publishes as stale
                    ver = other.owned[k].version
                    src = other
                    break
            if val is None:
                val = np.zeros(self.spec.sizes[k], np.float32)
            if src is not None and src.id not in still_holding:
                # transfer: the donor relinquishes responsibility (keeps a
                # cached copy for LoadModel, like any non-holder)
                src.cache[k] = src.owned.pop(k).value
                src._unsubscribe_partition(k)
            self.owned[k] = PartitionState(value=val, eps=eps, version=ver)
            self._subscribe_partition(k)
            # account for the partition transfer over the wire (one-time f32
            # bootstrap: join transfers stay uncompressed in every wire mode)
            self.net.pubsub.publish(
                MEMBER_TOPIC, self.id, ("join", self.id, k), 64 + val.nbytes
            )
        _AGENTS[self.id] = self

    # -- UpdateModel ---------------------------------------------------------
    def update_model(self, delta: np.ndarray, round_idx: int) -> None:
        """Send each partition's delta slice to one responsible agent."""
        if not self.live:
            return
        offsets = self.spec.offsets()
        for k in range(self.spec.num_partitions):
            sl = delta[offsets[k] : offsets[k] + self.spec.sizes[k]]
            if k in self.owned:
                # local contribution to my own partition: no network traffic
                self.owned[k].push_delta(sl)
                continue
            holders = self.table.holders_of(k)
            if not holders:
                continue
            # deterministic load-balancing over holders
            target = holders[(round_idx + self.id) % len(holders)]
            err = self._delta_err.get(k)
            if err is None:
                err = np.zeros(sl.shape[0], np.float32)
            payload, nb, new_err = self.wire.encode_delta(sl.astype(np.float32), err)
            self._delta_err[k] = new_err
            self.net.pubsub.send(
                UPDATE_TOPIC,
                self.id,
                target,
                (k, payload),
                nbytes=nb,
            )

    # -- holder side ---------------------------------------------------------
    def collect(self) -> None:
        """Drain incoming delta messages into pending buffers."""
        if not self.live:
            return
        for msg in self.net.pubsub.drain(self.id, UPDATE_TOPIC):
            k, wp = msg.payload
            if k in self.owned:
                self.owned[k].push_delta(self.wire.decode(wp))
                self._requesters.setdefault(k, []).append(msg.sender)

    def serve_replies(self) -> None:
        """After aggregating, reply to every requester with the fresh
        sub-vector (the UpdateModel reply of the paper)."""
        if not self.live:
            return
        for k, requesters in self._requesters.items():
            for requester in requesters:
                self.serve_reply(requester, k)
        self._requesters.clear()

    def aggregate(self) -> None:
        """Paper §2.2: the holder subtracts the received deltas weighted by
        eps, with eps <- alpha*eps + (1-alpha)*(1/r). Since eps's fixed point
        is 1/r, the coherent reading is w_k <- w_k - eps * SUM(deltas): the
        steady-state update is then the MEAN delta, matching centralized
        FedAvg (we verified the mean*eps reading double-normalizes by r and
        slows convergence r-fold — see EXPERIMENTS.md). eps is refreshed from
        the current r BEFORE applying, which bounds the first-round overshoot."""
        if not self.live:
            return
        for k, st in self.owned.items():
            deltas = st.drain_pending()
            if deltas is None:
                continue
            r = deltas.shape[0]
            st.eps = self.alpha * st.eps + (1.0 - self.alpha) / r
            agg = deltas.sum(axis=0)
            # Apply w - eps*agg with ONE f32 rounding: XLA's CPU backend
            # contracts the multiply-subtract into an FMA, and the device
            # engines must stay bit-comparable to this oracle. The f64
            # product of two f32 values is exact, so the final cast is the
            # single rounding an FMA performs.
            eps32 = np.float64(np.float32(st.eps))
            st.value = (
                st.value.astype(np.float64) - eps32 * agg.astype(np.float64)
            ).astype(np.float32)
            st.version += 1

    def _subscribe_partition(self, k: int) -> None:
        """Paper: 'Every device holding that replication subscribes to its
        topic' — one pub/sub topic per partition."""
        self.net.pubsub.subscribe(f"{REPLICA_TOPIC}/{k}", self.id)

    def _unsubscribe_partition(self, k: int) -> None:
        self.net.pubsub.unsubscribe(f"{REPLICA_TOPIC}/{k}", self.id)

    def sync_replicas(self, round_idx: int) -> None:
        """rho > 1: exchange replica values on the partition topic and average
        (replica consensus). The paper does this through pub/sub topics, one
        per partition."""
        if not self.live:
            return
        for k, st in self.owned.items():
            if self.table.replication(k) <= 1:
                continue
            payload, nb = self.wire.encode_value(st.value)
            self.net.pubsub.publish(
                f"{REPLICA_TOPIC}/{k}", self.id, (k, payload, st.version), nb
            )

    def merge_replicas(self) -> None:
        if not self.live:
            return
        incoming: Dict[int, List[np.ndarray]] = {}
        for msg in self.net.pubsub.drain(self.id, REPLICA_TOPIC):
            k, wp, ver = msg.payload
            val = self.wire.decode(wp)
            # a delayed replica value published in an earlier round carries an
            # older version; mean-merging it next to fresh values would drag
            # the partition backwards — discard anything staler than us
            if k in self.owned and ver >= self.owned[k].version:
                incoming.setdefault(k, []).append(val)
        for k, vals in incoming.items():
            st = self.owned[k]
            st.value = np.mean(np.stack([st.value] + vals), axis=0)

    def serve_reply(self, requester: int, k: int) -> None:
        """Reply to an UpdateModel with the fresh global sub-vector."""
        st = self.owned.get(k)
        if st is None or not self.live:
            return
        payload, nb = self.wire.encode_value(st.value)
        self.net.pubsub.send(REPLY_TOPIC, self.id, requester, (k, payload), nb)

    def receive_replies(self) -> None:
        if not self.live:
            return
        for msg in self.net.pubsub.drain(self.id, REPLY_TOPIC):
            k, wp = msg.payload
            self.cache[k] = self.wire.decode(wp)

    # -- initial parameter collection (paper: 'each agent initially contacts
    # enough agents to collect the global parameters') -----------------------
    def request_missing(self, round_idx: int = 0) -> None:
        if not self.live:
            return
        for k in range(self.spec.num_partitions):
            if k in self.owned or k in self.cache:
                continue
            holders = self.table.holders_of(k)
            if not holders:
                continue
            target = holders[(round_idx + self.id) % len(holders)]
            self.net.pubsub.send(FETCH_TOPIC, self.id, target, (k,), nbytes=16)

    def serve_fetches(self) -> None:
        if not self.live:
            return
        for msg in self.net.pubsub.drain(self.id, FETCH_TOPIC):
            (k,) = msg.payload
            self.serve_reply(msg.sender, k)

    # -- LoadModel -------------------------------------------------------------
    def load_model(self) -> np.ndarray:
        """Assemble the full W from owned partitions + cache. Partitions never
        seen fall back to zeros (cold cache, only possible before round 1)."""
        offsets = self.spec.offsets()
        w = np.zeros(self.spec.total, np.float32)
        for k in range(self.spec.num_partitions):
            if k in self.owned:
                w[offsets[k] : offsets[k] + self.spec.sizes[k]] = self.owned[k].value
            elif k in self.cache:
                w[offsets[k] : offsets[k] + self.spec.sizes[k]] = self.cache[k]
        return w

    # -- Snapshot hooks ----------------------------------------------------------
    # Used by the vectorized engine's churn re-snapshot (fl/vectorized.py):
    # at a membership-event boundary the dense device planes are written back
    # into the scalar agents (import), the event round replays on the scalar
    # oracle, and the next fused span harvests the updated state (export).
    def export_state(self) -> dict:
        """Protocol state as plain dicts of arrays/scalars: owned partition
        values with their (eps, version), the cached global parts, and the
        int8 error-feedback residuals. Values are the live arrays, not
        copies — callers snapshot into dense planes immediately."""
        return {
            "owned": {
                k: (st.value, st.eps, st.version) for k, st in self.owned.items()
            },
            "cache": dict(self.cache),
            "delta_err": dict(self._delta_err),
        }

    def import_state(
        self,
        owned: Dict[int, Tuple[np.ndarray, float, int]],
        cache: Dict[int, np.ndarray],
        delta_err: Optional[Dict[int, np.ndarray]] = None,
    ) -> None:
        """Overwrite protocol state from dense-plane values. Only partitions
        this agent currently owns (per the shared table) are accepted; the
        pending delta buffers reset (the caller re-injects in-flight messages
        through the pubsub instead)."""
        for k, (val, eps, ver) in owned.items():
            st = self.owned.get(k)
            if st is None:
                continue
            st.value = np.asarray(val, np.float32).copy()
            st.eps = float(eps)
            st.version = int(ver)
            st.pending_n = 0
        self.cache = {k: np.asarray(v, np.float32).copy() for k, v in cache.items()}
        if delta_err is not None:
            self._delta_err = {
                k: np.asarray(v, np.float32).copy() for k, v in delta_err.items()
            }

    # -- Terminate ---------------------------------------------------------------
    def terminate(self) -> None:
        """Graceful leave: upload owned partitions to the content store, hand
        off responsibility (least-loaded agents), broadcast the reassignment.
        New holders merge the uploaded value into theirs (paper §2.2)."""
        uploads: Dict[int, str] = {}
        for k, st in self.owned.items():
            cid = self.net.store.add(st.value.tobytes())
            uploads[k] = cid
        handoff = self.table.leave(self.id)
        for k, new_holder in handoff.items():
            payload = ("handoff", k, uploads[k], new_holder)
            self.net.pubsub.publish(MEMBER_TOPIC, self.id, payload, 96)
            if new_holder is not None and new_holder in _AGENTS:
                dst = _AGENTS[new_holder]
                uploaded = np.frombuffer(self.net.store.cat(uploads[k]), np.float32)
                if k in dst.owned:
                    dst.owned[k].value = 0.5 * (dst.owned[k].value + uploaded)
                else:
                    dst.owned[k] = PartitionState(value=uploaded.copy())
                    dst._subscribe_partition(k)
        for k in list(self.owned):
            self._unsubscribe_partition(k)
        self.owned.clear()
        self.live = False
        for topic in (UPDATE_TOPIC, REPLY_TOPIC, REPLICA_TOPIC, MEMBER_TOPIC, FETCH_TOPIC):
            self.net.pubsub.unsubscribe(topic, self.id)
        _AGENTS.pop(self.id, None)

    def crash(self) -> None:
        """Unexpected failure: no upload, no broadcast. Surviving replicas (or
        the checkpoint layer) must cover; the table reassigns ownership.

        The reassignment must also seed the DATA plane: ``fail()`` hands an
        orphaned partition to a new holder, and without a ``PartitionState``
        that holder drops every incoming delta (``collect`` checks
        ``k in self.owned``) and serves no replies — freezing the partition
        at stale cache values forever. Seed the new holder from a surviving
        replica when one exists, else its own cached copy, else zeros, and
        subscribe it to the partition topic."""
        handoff = self.table.fail(self.id)
        for k, new_holder in handoff.items():
            if new_holder is None or new_holder not in _AGENTS:
                continue
            dst = _AGENTS[new_holder]
            if k in dst.owned:
                continue
            val, ver = None, 0
            for h in self.table.holders_of(k):
                peer = _AGENTS.get(h)
                if peer is not None and peer.id != new_holder and k in peer.owned:
                    val = peer.owned[k].value.copy()
                    ver = peer.owned[k].version  # stay mergeable with survivors
                    break
            if val is None:
                cached = dst.cache.pop(k, None)
                val = (
                    cached.astype(np.float32).copy()
                    if cached is not None
                    else np.zeros(self.spec.sizes[k], np.float32)
                )
            # fresh eps; version 0 is safe here — an orphaned partition has
            # no surviving co-holders whose publishes we could lag behind
            dst.owned[k] = PartitionState(value=val, version=ver)
            dst._subscribe_partition(k)
        for k in list(self.owned):
            self._unsubscribe_partition(k)
        self.owned.clear()
        self.live = False
        _AGENTS.pop(self.id, None)


# registry used by the in-process simulation to resolve peers (stands in for
# the DHT lookup of agent addresses in real IPFS)
_AGENTS: Dict[int, IPLSAgent] = {}


def reset_registry() -> None:
    _AGENTS.clear()


def register(agent: IPLSAgent) -> None:
    _AGENTS[agent.id] = agent


def lookup(agent_id: int) -> Optional[IPLSAgent]:
    return _AGENTS.get(agent_id)
