"""IPLS core: the paper's contribution.

  partition.py    control plane — pi/rho partition assignment, join/leave
  aggregation.py  data plane math — eps-staleness masked aggregation
  api.py          the middleware API: Init/UpdateModel/LoadModel/Terminate
  sharded.py      the TPU-mesh mapping — IPLS as reduce-scatter / all-gather
"""
from repro.core.partition import PartitionSpec, PartitionTable, flatten_params, unflatten_params
from repro.core.aggregation import (
    EpsState,
    init_eps,
    update_eps,
    masked_mean,
    aggregate_partition,
    replica_consensus,
    apply_staleness_decay,
)
from repro.core.api import IPLSAgent, reset_registry
from repro.core.sharded import (
    IplsTrainState,
    IplsStepConfig,
    make_train_step,
    init_state,
    state_shardings,
    tree_shardings,
    spec_for_leaf,
    DEFAULT_RULES,
)

__all__ = [
    "PartitionSpec",
    "PartitionTable",
    "flatten_params",
    "unflatten_params",
    "EpsState",
    "init_eps",
    "update_eps",
    "masked_mean",
    "aggregate_partition",
    "replica_consensus",
    "apply_staleness_decay",
    "IPLSAgent",
    "reset_registry",
    "IplsTrainState",
    "IplsStepConfig",
    "make_train_step",
    "init_state",
    "state_shardings",
    "tree_shardings",
    "spec_for_leaf",
    "DEFAULT_RULES",
]
