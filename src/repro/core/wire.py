"""Wire codecs: how partition payloads travel the simulated network.

Two formats, selected by ``SimConfig.wire_dtype``:

  * ``"f32"``  — raw float32 values; N values cost 4N bytes.
  * ``"int8"`` — block-int8 with per-block power-of-two scales (the
    ``kernels/quantize`` format): N values cost N + 4*ceil(N/BLOCK) bytes,
    ~4x less. Delta (UpdateModel) sends carry an error-feedback residual so
    quantization noise telescopes instead of biasing convergence
    (Karimireddy et al., arXiv:1901.09847); value transfers (fetch replies,
    replica publishes) are stateless — every holder of the same version must
    put the identical payload on the wire.

Why power-of-two scales instead of the usual ``absmax/127``: every codec op
becomes EXACT in f32 — ``x * 2**-e`` scales without rounding, ``q * 2**e``
dequantizes without rounding, and the residual ``x - q*2**e`` subtracts an
exactly-representable product. That makes the codec bit-stable under any
compiler fusion (no reciprocal rewrites of a division, no FMA contraction of
an inexact product), which is what lets the scalar oracle (numpy), the
vectorized engine (XLA), and the Pallas kernel produce identical bits from
identical inputs — the engine-equivalence tests rely on it. The cost is a
quantization step up to 2x coarser than ``absmax/127`` (the scale rounds UP
to the next power of two); error feedback absorbs the difference.

Per block of 1024 values: ``e`` is chosen so ``absmax/scale`` lands in
[64, 128) (``scale = 2**(E-6)`` for ``absmax = m * 2**E``), codes clip to
[-127, 127]. Blocks whose absmax falls below ``2**-120`` (including all-zero
blocks) transmit scale 0 and all-zero codes; their values ride the error
residual instead.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024  # must match kernels/quantize BLOCK (asserted in tests)

# Biased-exponent threshold below which a block is sent as all-zeros: the
# inverse scale 2**(6-E) must stay a normal f32, which needs e0 >= 7.
_EMIN = 6

# What travels in a pubsub payload slot: raw f32 values, or (codes, scales).
WirePayload = Union[np.ndarray, Tuple[np.ndarray, np.ndarray]]


def num_blocks(n: int) -> int:
    return -(-n // BLOCK)


def wire_size(n: int, wire_dtype: str) -> int:
    """Closed-form wire bytes of one n-element payload."""
    if wire_dtype == "int8":
        return n + 4 * num_blocks(n)
    return 4 * n


def _np_pow2_scales(absmax: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(scale, inv_scale) per block, both exact powers of two (numpy)."""
    bits = np.ascontiguousarray(absmax, np.float32).view(np.int32)
    e0 = bits >> 23  # biased exponent; absmax >= 0 so the sign bit is clear
    zero = e0 <= _EMIN
    e0c = np.maximum(e0, _EMIN + 1)
    scale = ((e0c - _EMIN) << 23).astype(np.int32).view(np.float32)
    inv = (((127 + 133) - e0c) << 23).astype(np.int32).view(np.float32)
    z32 = np.float32(0.0)
    return np.where(zero, z32, scale), np.where(zero, z32, inv)


def _np_quantize(x: np.ndarray, err: np.ndarray):
    """Blockwise int8 quantize, numpy — bit-exact with the jnp row helpers
    and the kernels/quantize Pallas kernel (all ops are exact, see module
    docstring)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xb = np.pad(x.astype(np.float32), (0, pad)) + np.pad(err.astype(np.float32), (0, pad))
    xb = xb.reshape(-1, BLOCK)
    absmax = np.max(np.abs(xb), axis=1)
    scale, inv = _np_pow2_scales(absmax)
    q = np.clip(np.round(xb * inv[:, None]), -127, 127).astype(np.int8)
    deq = q.astype(np.float32) * scale[:, None]
    new_err = (xb - deq).reshape(-1)[:n]
    return q.reshape(-1), scale, new_err


def _np_dequantize(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    n = q.shape[0]
    pad = (-n) % BLOCK
    qb = np.pad(q, (0, pad)).reshape(-1, BLOCK).astype(np.float32)
    return (qb * scales[:, None]).reshape(-1)[:n]


class F32Wire:
    """Identity codec: payloads are the f32 values themselves."""

    dtype = "f32"

    def encode_value(self, x: np.ndarray) -> Tuple[WirePayload, int]:
        payload = np.array(x, dtype=np.float32)  # copy: wire snapshot, not a view
        return payload, payload.nbytes

    def encode_delta(self, x, err) -> Tuple[WirePayload, int, np.ndarray]:
        payload, nb = self.encode_value(x)
        return payload, nb, err

    def decode(self, payload: WirePayload) -> np.ndarray:
        return np.asarray(payload, dtype=np.float32)


class Int8Wire:
    """Block-int8 codec: payloads are (codes int8, per-block pow2 scales)."""

    dtype = "int8"

    def encode_value(self, x: np.ndarray) -> Tuple[WirePayload, int]:
        n = x.shape[0]
        q, s, _ = _np_quantize(np.asarray(x, dtype=np.float32), np.zeros(n, np.float32))
        q = q[:n]
        return (q, s), q.nbytes + s.nbytes

    def encode_delta(self, x, err) -> Tuple[WirePayload, int, np.ndarray]:
        n = x.shape[0]
        q, s, new_err = _np_quantize(np.asarray(x, dtype=np.float32), err)
        q = q[:n]
        return (q, s), q.nbytes + s.nbytes, new_err

    def decode(self, payload: WirePayload) -> np.ndarray:
        q, s = payload
        return _np_dequantize(q, s)


def make_wire(wire_dtype: str):
    if wire_dtype == "f32":
        return F32Wire()
    if wire_dtype == "int8":
        return Int8Wire()
    raise ValueError(f"unknown wire_dtype {wire_dtype!r} (expected 'f32' or 'int8')")


# ---------------------------------------------------------------------------
# jnp row helpers for the vectorized engine: quantize whole (..., M) planes
# (M a multiple of BLOCK; partition tails padded with zeros quantize to zero
# blocks, matching the scalar codec's per-slice padding exactly).
# ---------------------------------------------------------------------------


def _jnp_pow2_scales(absmax: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(scale, inv_scale), exact powers of two — jnp mirror of the numpy
    helper. Exponent arithmetic on the f32 bit pattern is exact integer math,
    so the result is identical bits in every compilation context."""
    bits = jax.lax.bitcast_convert_type(absmax.astype(jnp.float32), jnp.int32)
    e0 = bits >> 23
    zero = e0 <= _EMIN
    e0c = jnp.maximum(e0, _EMIN + 1)
    scale = jax.lax.bitcast_convert_type((e0c - _EMIN) << 23, jnp.float32)
    inv = jax.lax.bitcast_convert_type(((127 + 133) - e0c) << 23, jnp.float32)
    return jnp.where(zero, 0.0, scale), jnp.where(zero, 0.0, inv)


def quantize_rows(x: jax.Array, err: jax.Array):
    """x, err: (..., M), M % BLOCK == 0. Returns (q int8 (..., M),
    scales (..., M//BLOCK) f32, new_err (..., M) f32)."""
    shp = x.shape
    nb = shp[-1] // BLOCK
    xb = (x.astype(jnp.float32) + err.astype(jnp.float32)).reshape(*shp[:-1], nb, BLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale, inv = _jnp_pow2_scales(absmax)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127)
    deq = q * scale[..., None]
    new_err = (xb - deq).reshape(shp)
    return q.astype(jnp.int8).reshape(shp), scale, new_err


def dequantize_rows(q: jax.Array, scales: jax.Array) -> jax.Array:
    """q: (..., M) int8, scales: (..., M//BLOCK). Returns f32 (..., M)."""
    shp = q.shape
    nb = shp[-1] // BLOCK
    qb = q.reshape(*shp[:-1], nb, BLOCK).astype(jnp.float32)
    return (qb * scales[..., None]).reshape(shp)


def qdq_rows(x: jax.Array) -> jax.Array:
    """Stateless quantize->dequantize: what a value payload looks like after
    one trip over the int8 wire."""
    q, s, _ = quantize_rows(x, jnp.zeros_like(x))
    return dequantize_rows(q, s)
