"""IPLS partition assignment (paper §2.1, "Model partitioning and distribution").

The global parameter vector W is split into K partitions. Every agent is
responsible for at least ``pi`` partitions; every partition is replicated at
most ``rho`` times. Assignment follows the paper's rule: a joining agent takes
partitions from the agent that currently stores the most partitions
(max-overloaded), preferring the least-replicated partitions; ties broken
deterministically by partition id.

This module is pure Python/numpy bookkeeping (no jax): it is the control
plane. The data plane (actual parameter math) lives in aggregation.py and
sharded.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

AgentId = int
PartitionId = int


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Static description of how W is split into K partitions.

    ``sizes[k]`` is the number of scalar parameters in partition k. Partitions
    are contiguous ranges of the flattened parameter vector, in order.
    """

    sizes: Tuple[int, ...]

    @property
    def num_partitions(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        return int(sum(self.sizes))

    def offsets(self) -> Tuple[int, ...]:
        # memoized: update_model/load_model call this on every round; the
        # frozen dataclass still has a __dict__, so plain item assignment
        # caches without tripping the frozen __setattr__.
        cached = self.__dict__.get("_offsets")
        if cached is not None:
            return cached
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        self.__dict__["_offsets"] = tuple(out)
        return self.__dict__["_offsets"]

    @staticmethod
    def even(total: int, k: int) -> "PartitionSpec":
        """Split ``total`` parameters into ``k`` near-equal partitions."""
        if k <= 0:
            raise ValueError("k must be positive")
        base, rem = divmod(total, k)
        sizes = tuple(base + (1 if i < rem else 0) for i in range(k))
        return PartitionSpec(sizes=sizes)


class PartitionTable:
    """Mutable responsibility table: which agent stores which partition.

    Invariants (checked by ``validate``):
      * every live agent stores >= min(pi, K) partitions (pi clamped to K);
      * every partition is stored by <= rho agents;
      * every partition is stored by >= 1 agent whenever any agent is live.
    """

    def __init__(self, num_partitions: int, pi: int, rho: int):
        if num_partitions <= 0:
            raise ValueError("num_partitions must be positive")
        if pi <= 0 or rho <= 0:
            raise ValueError("pi and rho must be positive")
        self.k = num_partitions
        self.pi = min(pi, num_partitions)
        self.rho = rho
        # partition -> ordered list of responsible agents
        self._holders: Dict[PartitionId, List[AgentId]] = {
            p: [] for p in range(num_partitions)
        }
        self._agents: Dict[AgentId, List[PartitionId]] = {}

    # -- queries ----------------------------------------------------------
    @property
    def agents(self) -> List[AgentId]:
        return sorted(self._agents)

    def partitions_of(self, agent: AgentId) -> List[PartitionId]:
        return list(self._agents.get(agent, []))

    def holders_of(self, partition: PartitionId) -> List[AgentId]:
        return list(self._holders[partition])

    def replication(self, partition: PartitionId) -> int:
        return len(self._holders[partition])

    def load(self, agent: AgentId) -> int:
        return len(self._agents.get(agent, ()))

    def coverage(self) -> bool:
        """True iff every partition has at least one live holder."""
        return all(len(h) > 0 for h in self._holders.values())

    # -- membership -------------------------------------------------------
    def bootstrap(self, agent: AgentId) -> List[PartitionId]:
        """First agent: stores ALL partitions (paper: 'the agent that
        initiated the training process stores all the partitions')."""
        if self._agents:
            raise RuntimeError("bootstrap() on a non-empty table")
        self._agents[agent] = list(range(self.k))
        for p in range(self.k):
            self._holders[p].append(agent)
        return self.partitions_of(agent)

    def join(self, agent: AgentId) -> List[PartitionId]:
        """Paper's join rule. The new agent acquires up to ``pi`` partitions:

        repeatedly take one partition from the most-overloaded donor
        (an agent with load > pi), choosing the donor's least-replicated
        partition — *transferring* responsibility. If no donor can give one
        up, *replicate* the globally least-replicated partition, as long as
        its replication < rho. An agent that cannot reach pi partitions keeps
        whatever it got (possibly none, matching the paper's example where
        late joiners store nothing once all partitions hit rho).
        """
        if agent in self._agents:
            raise ValueError(f"agent {agent} already joined")
        self._agents[agent] = []
        for _ in range(self.pi):
            if not self._take_one(agent):
                break
        return self.partitions_of(agent)

    def _take_one(self, agent: AgentId) -> bool:
        mine = set(self._agents[agent])
        # 1) transfer from the most-overloaded donor (load > pi)
        donors = [a for a in self._agents if a != agent and self.load(a) > self.pi]
        donors.sort(key=lambda a: (-self.load(a), a))
        for donor in donors:
            cands = [p for p in self._agents[donor] if p not in mine]
            if not cands:
                continue
            # least-replicated first, then lowest id
            cands.sort(key=lambda p: (self.replication(p), p))
            p = cands[0]
            self._agents[donor].remove(p)
            self._holders[p].remove(donor)
            self._attach(agent, p)
            return True
        # 2) replicate the least-replicated partition under rho
        cands = [
            p
            for p in range(self.k)
            if p not in mine and self.replication(p) < self.rho
        ]
        if not cands:
            return False
        cands.sort(key=lambda p: (self.replication(p), p))
        self._attach(agent, cands[0])
        return True

    def _attach(self, agent: AgentId, p: PartitionId) -> None:
        self._agents[agent].append(p)
        self._agents[agent].sort()
        self._holders[p].append(agent)

    def leave(self, agent: AgentId) -> Dict[PartitionId, Optional[AgentId]]:
        """Paper's Terminate(): hand off each partition this agent held to the
        least-loaded other agent not already holding it. Returns the handoff
        map partition -> new holder (None if the partition would be orphaned
        and no eligible agent exists — then it is given to the least-loaded
        agent regardless of rho to preserve coverage, or truly orphaned if no
        agents remain).
        """
        if agent not in self._agents:
            raise ValueError(f"agent {agent} not present")
        held = self._agents.pop(agent)
        handoff: Dict[PartitionId, Optional[AgentId]] = {}
        for p in held:
            self._holders[p].remove(agent)
            if self._holders[p]:
                handoff[p] = None  # still replicated; no handoff needed
                continue
            # orphaned: assign to least-loaded agent (coverage beats rho)
            others = sorted(self._agents, key=lambda a: (self.load(a), a))
            if not others:
                handoff[p] = None
                continue
            new_holder = others[0]
            self._attach(new_holder, p)
            handoff[p] = new_holder
        return handoff

    def fail(self, agent: AgentId) -> Dict[PartitionId, Optional[AgentId]]:
        """Unexpected failure: same reassignment as leave(), but semantically
        the data-plane must recover partition values from replicas (or from
        the last checkpoint when replication was 1)."""
        return self.leave(agent)

    # -- validation -------------------------------------------------------
    def validate(self) -> None:
        for p, holders in self._holders.items():
            if len(holders) != len(set(holders)):
                raise AssertionError(f"duplicate holders for partition {p}")
            if len(holders) > max(self.rho, 1) and len(self._agents) > 1:
                # rho may be exceeded only transiently by coverage-preserving
                # handoff; flag everything else.
                raise AssertionError(
                    f"partition {p} over-replicated: {len(holders)} > rho={self.rho}"
                )
        for a, parts in self._agents.items():
            for p in parts:
                if a not in self._holders[p]:
                    raise AssertionError(f"table inconsistent for agent {a}, part {p}")
        if self._agents and not self.coverage():
            # coverage can only break when every agent left
            raise AssertionError("partition coverage lost while agents remain")

    def as_lookup(self) -> Dict[PartitionId, List[AgentId]]:
        """The paper's 'lookup table': partition -> responsible agents."""
        return {p: list(h) for p, h in self._holders.items()}


def flatten_params(params) -> Tuple[np.ndarray, List[Tuple[str, Tuple[int, ...]]]]:
    """Flatten a pytree-like dict of numpy arrays into one vector + layout."""
    layout: List[Tuple[str, Tuple[int, ...]]] = []
    chunks: List[np.ndarray] = []

    def walk(prefix: str, node) -> None:
        if isinstance(node, Mapping):
            for key in sorted(node):
                walk(f"{prefix}/{key}" if prefix else str(key), node[key])
        else:
            arr = np.asarray(node)
            layout.append((prefix, arr.shape))
            chunks.append(arr.reshape(-1))

    walk("", params)
    if not chunks:
        return np.zeros((0,), np.float32), layout
    return np.concatenate(chunks), layout


def unflatten_params(vec: np.ndarray, layout: Sequence[Tuple[str, Tuple[int, ...]]]):
    """Inverse of flatten_params (returns nested dict)."""
    out: Dict = {}
    off = 0
    for name, shape in layout:
        size = int(np.prod(shape)) if shape else 1
        arr = vec[off : off + size].reshape(shape)
        off += size
        node = out
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out
