"""Summarize telemetry JSONL metric streams from the command line.

    python -m repro.telemetry.report METRICS.jsonl [--json]

Prints a per-stream digest: rounds covered, traffic by channel, drop and
delay statistics, accuracy trajectory endpoints, and (with ``--json``) the
digest as machine-readable JSON. Accepts multiple files and reports each.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.telemetry.schema import CHANNELS, SCHEMA_VERSION


def load_stream(path: str):
    """Returns (header, rows). Raises ValueError on schema mismatch."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty stream")
    head = json.loads(lines[0])
    ver = head.get("schema_version")
    if ver != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema_version {ver!r} != supported {SCHEMA_VERSION}"
        )
    return head, [json.loads(ln) for ln in lines[1:]]


def summarize(rows: List[dict]) -> dict:
    if not rows:
        return {"rounds": 0}
    channels = {}
    for ch in CHANNELS:
        msgs = sum(r[f"msgs_{ch}"] for r in rows)
        if msgs == 0:
            continue
        channels[ch] = {
            "msgs": msgs,
            "bytes": sum(r[f"bytes_{ch}"] for r in rows),
            "drops": sum(r[f"drops_{ch}"] for r in rows),
        }
    hist = [0] * max(len(r["delay_hist"]) for r in rows)
    for r in rows:
        for i, n in enumerate(r["delay_hist"]):
            hist[i] += n
    delivered = sum(hist)
    last = rows[-1]
    return {
        "rounds": len(rows),
        "round_range": [rows[0]["round"], last["round"]],
        "active_last": last["active"],
        "channels": channels,
        "drops_offline": sum(r["drops_offline"] for r in rows),
        "delivered": delivered,
        "mean_delay_ticks": (
            sum(i * n for i, n in enumerate(hist)) / delivered if delivered else 0.0
        ),
        "delay_hist": hist,
        "acc_first": rows[0]["acc_mean"],
        "acc_last": last["acc_mean"],
        "acc_best": max(r["acc_mean"] for r in rows),
        "bytes_total": last["bytes_total"],
        "msgs_total": last["msgs_total"],
        "drops_total": last["drops_total"],
    }


def _print_human(path: str, head: dict, s: dict) -> None:
    print(f"== {path}")
    meta = head.get("meta") or {}
    if meta:
        print(f"   meta: {json.dumps(meta, sort_keys=True)}")
    if not s["rounds"]:
        print("   (no rows)")
        return
    lo, hi = s["round_range"]
    print(f"   rounds {lo}..{hi} ({s['rounds']} rows), active={s['active_last']}")
    for ch, c in s["channels"].items():
        print(
            f"   {ch:13s} msgs={c['msgs']:<8d} bytes={c['bytes']:<12d}"
            f" drops={c['drops']}"
        )
    print(
        f"   delivered={s['delivered']} mean_delay={s['mean_delay_ticks']:.3f} ticks"
        f" offline_drops={s['drops_offline']}"
    )
    print(
        f"   acc {s['acc_first']:.4f} -> {s['acc_last']:.4f}"
        f" (best {s['acc_best']:.4f})"
    )
    print(
        f"   totals: {s['msgs_total']} msgs, {s['bytes_total']} bytes,"
        f" {s['drops_total']} drops"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Summarize telemetry JSONL metric streams.",
    )
    ap.add_argument("paths", nargs="+", help="metric .jsonl files")
    ap.add_argument("--json", action="store_true", help="emit JSON digests")
    args = ap.parse_args(argv)

    out = {}
    for path in args.paths:
        try:
            head, rows = load_stream(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        out[path] = summarize(rows)
        if not args.json:
            _print_human(path, head, out[path])
    if args.json:
        print(json.dumps(out, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
