"""Observability for the IPLS reproduction: structured per-round metrics,
protocol event traces, and per-phase wall timing. Zero overhead when
disabled — engines hold ``NULL_TIMER`` and skip every tap, and the jitted
programs are unchanged (no extra outputs in the jaxpr).
"""
from repro.telemetry.recorder import MetricsRecorder
from repro.telemetry.schema import (
    CHANNELS,
    FINISH_KEYS,
    ROW_KEYS,
    SCHEMA_VERSION,
    TELEMETRY_SCHEMA,
)
from repro.telemetry.timing import NULL_TIMER, PhaseTimer, host_metadata
from repro.telemetry.trace import TraceWriter

__all__ = [
    "MetricsRecorder",
    "TraceWriter",
    "PhaseTimer",
    "NULL_TIMER",
    "host_metadata",
    "SCHEMA_VERSION",
    "CHANNELS",
    "FINISH_KEYS",
    "ROW_KEYS",
    "TELEMETRY_SCHEMA",
]
