"""Chrome trace-event JSON writer (perfetto / chrome://tracing viewable).

Two tracks:

  * pid 1 "protocol (simulated ticks)" — instant events for every scalar
    pubsub send/delivery/drop, one tid per agent, with the simulated tick
    counter as the timebase (1 tick = 1000 trace-us, so a round spans 4ms
    on the timeline and delayed deliveries visibly land in later rounds);
  * pid 2 "host (wall clock)" — complete ("X") spans for the engine phases
    recorded by ``PhaseTimer`` (fate draw, control replay, device calls,
    eval), in real microseconds since trace construction.

The output is the standard ``{"traceEvents": [...]}`` JSON object; open
it at https://ui.perfetto.dev or chrome://tracing.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

PID_PROTOCOL = 1
PID_HOST = 2

# one simulated tick = this many trace-timeline microseconds
TICK_US = 1000


class TraceWriter:
    def __init__(self) -> None:
        self.events: List[dict] = []
        self._t0 = time.perf_counter()

    # -- protocol track (simulated time) -----------------------------------
    def instant(
        self,
        name: str,
        tick: int,
        tid: int,
        args: Optional[Dict[str, object]] = None,
    ) -> None:
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": tick * TICK_US,
            "pid": PID_PROTOCOL,
            "tid": int(tid),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- host track (wall clock) -------------------------------------------
    def host_span(self, name: str, t0: float, dur_s: float, tid: int = 0) -> None:
        self.events.append(
            {
                "name": name,
                "ph": "X",
                "ts": (t0 - self._t0) * 1e6,
                "dur": dur_s * 1e6,
                "pid": PID_HOST,
                "tid": int(tid),
            }
        )

    # -- output --------------------------------------------------------------
    def to_dict(self) -> dict:
        meta = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": PID_PROTOCOL,
                "args": {"name": "protocol (simulated ticks)"},
            },
            {
                "name": "process_name",
                "ph": "M",
                "pid": PID_HOST,
                "args": {"name": "host (wall clock)"},
            },
        ]
        return {"traceEvents": meta + self.events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
