"""The shared per-round metric schema — the single source of truth.

Every engine (scalar pubsub oracle, vectorized per-round, multi-round
scanned) emits EXACTLY these keys per round, in this order, with values
that agree byte-for-byte across engines under identical configs (asserted
in tests/test_telemetry.py). The static-analysis rule PR04
(``repro.analysis.rules_protocol.MetricSchemaSymmetry``) checks that every
``finish_round`` emission site passes keys from this schema and that the
scalar and vectorized emitters stay mirrored; its hardcoded copy of these
tables is cross-checked against this module by tests/test_analysis.py.

Traffic keys are accumulated by the recorder's tap methods (the scalar
pubsub calls them per message; the vectorized control plane per channel
batch); the remaining keys arrive through one ``finish_round`` call per
round per engine. Derived keys (``acc_mean``/``acc_std``/``acc_max``) are
computed by the recorder itself from ``accs`` so both engines share one
float path.
"""
from __future__ import annotations

from typing import Dict, Tuple

SCHEMA_VERSION = 1

# message channels, in fate-stream order (fl/rounds.py CH_* constants)
CHANNELS: Tuple[str, ...] = (
    "fetch",
    "fetch_reply",
    "update",
    "update_reply",
    "replica",
    "member",
)

# keys an engine passes to MetricsRecorder.finish_round (PR04-checked)
FINISH_KEYS: Tuple[str, ...] = (
    "round",
    "active",
    "contrib",
    "eps",
    "delta_normsq",
    "value_normsq",
    "accs",
    "bytes_total",
    "msgs_total",
    "drops_total",
)


def _traffic_schema() -> Dict[str, str]:
    out: Dict[str, str] = {}
    for ch in CHANNELS:
        out[f"msgs_{ch}"] = f"{ch} messages sent this round"
        out[f"bytes_{ch}"] = f"{ch} payload bytes sent this round"
        out[f"drops_{ch}"] = f"{ch} messages lost to the fate stream this round"
    return out


# ordered key -> description catalogue (docs/TELEMETRY.md renders this)
TELEMETRY_SCHEMA: Dict[str, str] = {
    "round": "training round index",
    "active": "live, online agents this round",
    **_traffic_schema(),
    "drops_offline": "messages dropped because an endpoint was offline (churn)",
    "delay_hist": "histogram of delivered-message delays in ticks, 0..max_delay",
    "contrib": "per-(partition, replica-slot) contributor count r, k-major",
    "eps": "per-instance staleness weight eps after this round's recursion",
    "delta_normsq": "sum of squares of all agents' local-SGD deltas (f32)",
    "value_normsq": "sum of squares of the post-merge partition value plane (f32)",
    "accs": "per-evaluated-agent test accuracy (f32)",
    "acc_mean": "mean of accs (f64 over the f32 values)",
    "acc_std": "std of accs (f64 over the f32 values)",
    "acc_max": "max of accs",
    "bytes_total": "cumulative wire bytes since construction (== pubsub)",
    "msgs_total": "cumulative messages sent since construction (== pubsub)",
    "drops_total": "cumulative messages dropped since construction (== pubsub)",
}

ROW_KEYS: Tuple[str, ...] = tuple(TELEMETRY_SCHEMA)
