"""Per-phase wall timers: dispatch-level attribution for the round engines.

``PhaseTimer.phase(name)`` is a context manager accumulating count/seconds
per phase; when a ``TraceWriter`` is attached every phase also lands as a
Chrome trace "X" (complete) event on the host-wall-clock track. The
``NULL_TIMER`` singleton is what engines hold when telemetry is disabled —
its ``phase()`` is a shared no-op context manager, so the disabled-path
cost is one attribute lookup per phase.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Optional


class PhaseTimer:
    """Accumulates wall seconds per named phase.

    ``sync`` tells engines to ``jax.block_until_ready`` inside device
    phases so async dispatch cannot leak timed work across phases — only
    honest when a timer is actually attached.
    """

    sync = True

    def __init__(self, trace=None):
        self.trace = trace
        self.totals: Dict[str, list] = {}  # name -> [count, seconds]

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            ent = self.totals.setdefault(name, [0, 0.0])
            ent[0] += 1
            ent[1] += dt
            if self.trace is not None:
                self.trace.host_span(name, t0, dt)

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {
            name: {"count": c, "total_s": s, "mean_s": s / max(c, 1)}
            for name, (c, s) in sorted(self.totals.items())
        }


class _NullTimer:
    sync = False
    totals: Dict[str, list] = {}

    @contextmanager
    def phase(self, name: str):
        yield

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {}


NULL_TIMER = _NullTimer()


def host_metadata(timestamp: Optional[str] = None) -> Dict[str, object]:
    """Environment stamp for benchmark artifacts (BENCH_rounds.json):
    the context that makes cross-machine perf numbers comparable. The
    timestamp is passed in by the runner (benchmarks/run.py) so library
    code stays clock-free."""
    import os
    import platform
    import sys

    import jax
    import numpy as np

    return {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "jax_version": jax.__version__,
        "jax_backend": jax.default_backend(),
        "numpy_version": np.__version__,
        "timestamp": timestamp,
    }
