"""Device-side metric math shared by all engines.

The equivalence contract (scalar == vectorized == scanned, byte for byte)
extends to the f32 norm metrics, so the REDUCTION must be the same XLA
program everywhere: the fused engines inline ``metric_pair`` into their
device calls as an auxiliary output, while the scalar engine calls the
standalone jitted ``host_normsq`` on bitwise-identical planes. On the CPU
backend ``jnp.sum(x*x)`` lowers to the same deterministic loop-order
reduction in both contexts (verified empirically; asserted by the
equivalence tests every run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normsq(x):
    """Sum of squares, f32 in / f32 scalar out. Pure — safe to inline into
    any jitted engine body."""
    return jnp.sum(x * x)


def metric_pair(delta_plane, value_plane):
    """The per-round (delta_normsq, value_normsq) auxiliary output of the
    fused engines, as one (2,) f32 vector."""
    return jnp.stack([normsq(delta_plane), normsq(value_plane)])


_normsq_j = jax.jit(normsq)


def host_normsq(x: np.ndarray) -> float:
    """Scalar-engine entry point: the same jitted reduction, value pulled
    back to a python float (exact f32 round-trip)."""
    return float(np.asarray(_normsq_j(jnp.asarray(x, jnp.float32))))
