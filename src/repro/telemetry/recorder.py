"""MetricsRecorder: one structured per-round metric stream for every engine.

Two feeding styles, one schema (``telemetry.schema.TELEMETRY_SCHEMA``):

  * the scalar pubsub taps ``on_send`` / ``on_fate`` / ``on_delivery`` /
    ``on_offline_drop`` per message (the recorder maps topic + tick counter
    onto the channel exactly like ``MessageFates.pubsub_fate`` maps fates);
  * the vectorized control plane calls ``on_channel`` / ``on_delays`` /
    ``on_delivered`` with whole channel batches per round.

Both end with ONE ``finish_round(...)`` call per round per engine — the
emission site the PR04 analysis rule pins to the schema — which folds the
accumulated traffic with the round's state metrics into an ordered row.
Rows and their JSONL serialization are byte-for-byte identical across
engines under identical configs (tests/test_telemetry.py).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.api import (
    FETCH_TOPIC,
    REPLICA_TOPIC,
    REPLY_TOPIC,
    UPDATE_TOPIC,
)
from repro.telemetry.schema import CHANNELS, ROW_KEYS, SCHEMA_VERSION
from repro.telemetry.timing import PhaseTimer
from repro.telemetry.trace import TraceWriter


class MetricsRecorder:
    def __init__(
        self,
        *,
        ticks_per_round: int,
        max_delay_ticks: int,
        trace: Optional[TraceWriter] = None,
    ):
        self.rows: List[dict] = []
        self.trace = trace
        self.timer = PhaseTimer(trace=trace)
        self._ticks = int(ticks_per_round)
        self._bins = int(max_delay_ticks) + 1
        self._acc: Dict[int, dict] = {}  # round -> in-progress traffic row

    # -- traffic accumulator -------------------------------------------------
    def _blank(self) -> dict:
        d: dict = {}
        for ch in CHANNELS:
            d[f"msgs_{ch}"] = 0
            d[f"bytes_{ch}"] = 0
            d[f"drops_{ch}"] = 0
        d["drops_offline"] = 0
        d["delay_hist"] = [0] * self._bins
        return d

    def _traffic(self, rnd: int) -> dict:
        tr = self._acc.get(rnd)
        if tr is None:
            tr = self._acc[rnd] = self._blank()
        return tr

    def _channel(self, topic: str, counter: int) -> str:
        """Topic + tick phase -> channel name; the same mapping
        ``MessageFates.pubsub_fate`` uses for fate keys."""
        if topic == UPDATE_TOPIC:
            return "update"
        if topic == FETCH_TOPIC:
            return "fetch"
        if topic == REPLY_TOPIC:
            return "fetch_reply" if counter % self._ticks == 1 else "update_reply"
        if topic.startswith(REPLICA_TOPIC):
            return "replica"
        return "member"

    # -- scalar pubsub taps (one call per message) ---------------------------
    def on_send(self, topic: str, counter: int, sender: int, nbytes: int) -> None:
        ch = self._channel(topic, counter)
        tr = self._traffic(counter // self._ticks)
        tr[f"msgs_{ch}"] += 1
        tr[f"bytes_{ch}"] += int(nbytes)
        if self.trace is not None:
            self.trace.instant(f"send {ch}", counter, sender, {"bytes": int(nbytes)})

    def on_fate(
        self,
        topic: str,
        counter: int,
        sender: int,
        recipient: int,
        delivered: bool,
        delay: int,
    ) -> None:
        ch = self._channel(topic, counter)
        tr = self._traffic(counter // self._ticks)
        if delivered:
            tr["delay_hist"][int(delay)] += 1
        else:
            tr[f"drops_{ch}"] += 1
            if self.trace is not None:
                self.trace.instant(f"drop {ch}", counter, recipient)

    def on_delivery(
        self,
        topic: str,
        sent_counter: int,
        counter: int,
        sender: int,
        recipient: int,
        nbytes: int,
    ) -> None:
        # trace-only: channel named by the SEND tick (delayed replies keep
        # their phase), timestamped at the delivery tick
        if self.trace is not None:
            ch = self._channel(topic, sent_counter)
            self.trace.instant(
                f"recv {ch}", counter, recipient, {"from": int(sender)}
            )

    def on_offline_drop(self, counter: int) -> None:
        self._traffic(counter // self._ticks)["drops_offline"] += 1

    # -- vectorized control-plane feeds (one call per channel batch) ---------
    def on_offline_drops(self, rnd: int, count: int) -> None:
        """Batch form of on_offline_drop keyed by round: the vectorized
        control plane accounts a whole span's offline-recipient drops in one
        call per round rather than per message."""
        if count:
            self._traffic(rnd)["drops_offline"] += int(count)

    def on_channel(
        self, rnd: int, channel: str, msgs: int, nbytes: int, drops: int
    ) -> None:
        tr = self._traffic(rnd)
        tr[f"msgs_{channel}"] += int(msgs)
        tr[f"bytes_{channel}"] += int(nbytes)
        tr[f"drops_{channel}"] += int(drops)

    def on_delays(self, rnd: int, delays) -> None:
        """Fold an array of delivered-message delays (ticks) into the
        round's histogram."""
        delays = np.asarray(delays)
        if delays.size == 0:
            return
        hist = self._traffic(rnd)["delay_hist"]
        for d, n in zip(*np.unique(delays, return_counts=True)):
            hist[int(d)] += int(n)

    def on_delivered(self, rnd: int, delay: int, count: int) -> None:
        if count:
            self._traffic(rnd)["delay_hist"][int(delay)] += int(count)

    # -- the one emission site per engine ------------------------------------
    def finish_round(
        self,
        *,
        round: int,
        active: int,
        contrib,
        eps,
        delta_normsq: float,
        value_normsq: float,
        accs,
        bytes_total: int,
        msgs_total: int,
        drops_total: int,
    ) -> None:
        tr = self._acc.pop(round, None)
        if tr is None:
            tr = self._blank()
        accs32 = np.asarray(accs, np.float32)
        a64 = accs32.astype(np.float64)
        row = {
            "round": int(round),
            "active": int(active),
            **tr,
            "contrib": [int(x) for x in contrib],
            "eps": [float(x) for x in eps],
            "delta_normsq": float(delta_normsq),
            "value_normsq": float(value_normsq),
            "accs": [float(x) for x in accs32],
            "acc_mean": float(a64.mean()),
            "acc_std": float(a64.std()),
            "acc_max": float(a64.max()),
            "bytes_total": int(bytes_total),
            "msgs_total": int(msgs_total),
            "drops_total": int(drops_total),
        }
        assert tuple(row) == ROW_KEYS  # schema drift is a bug, not data
        self.rows.append(row)

    # -- serialization --------------------------------------------------------
    def jsonl_lines(self, meta: Optional[dict] = None) -> List[str]:
        """Line 1: stream header (schema version + caller metadata); then
        one compact-JSON row per round. Identical rows serialize to
        identical bytes (insertion order is schema order)."""
        head = {"schema_version": SCHEMA_VERSION, "meta": meta or {}}
        lines = [json.dumps(head, separators=(",", ":"))]
        lines += [json.dumps(r, separators=(",", ":")) for r in self.rows]
        return lines

    def write_jsonl(self, path: str, meta: Optional[dict] = None) -> None:
        with open(path, "w") as f:
            f.write("\n".join(self.jsonl_lines(meta)) + "\n")
