"""FlashAttention forward — Pallas TPU kernel (arXiv:2205.14135, adapted to
the TPU memory hierarchy: HBM -> VMEM tiles sized for the MXU, sequential
grid accumulation instead of warp-level parallelism).

Grid: (B*H, nQ, nK) — TPU executes the grid sequentially per core, so the
running-softmax state (m, l, acc) lives in VMEM scratch that persists across
the innermost K dimension. Causal blocks above the diagonal are skipped with
pl.when (no MXU work issued).

Block sizes: BQ=BK=128 (MXU-aligned); head_dim passes through whole (<=256).
VMEM working set: q(128xD) + k,v(128xD) + acc(128xD) f32 + logits(128x128)
~= 0.5 MiB at D=128 — far under the 16 MiB budget, leaving room for the
compiler's double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = float(np.finfo(np.float32).min)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal, scale, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    should_run = True
    if causal:
        should_run = ki * BK <= qi * BQ + BQ - 1  # any overlap with lower tri

    @pl.when(should_run)
    def _run():
        q = q_ref[0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (BQ, BK)
        if causal:
            rows = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
            cols = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
            logits = jnp.where(rows >= cols, logits, NEG_INF)
        m_prev = m_scr[...]                           # (BQ, 1)
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                   # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)               # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = True):
    """q,k,v: (B, H, S, D) with S % 128 == 0. Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    assert S % BQ == 0 and S % BK == 0, (S,)
    scale = 1.0 / np.sqrt(D)
    nq, nk = S // BQ, S // BK
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
