"""Pure-jnp oracle: causal (optionally sliding-window) attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def mha_ref(q, k, v, causal: bool = True, window: Optional[int] = None):
    """q,k,v: (B, H, S, D). fp32 softmax; returns (B, H, S, D) in q.dtype."""
    S = q.shape[2]
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        ii = jnp.arange(S)
        mask = ii[:, None] >= ii[None, :]
        if window is not None:
            mask &= ii[:, None] - ii[None, :] < window
        logits = jnp.where(mask[None, None], logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(q.dtype), v)
