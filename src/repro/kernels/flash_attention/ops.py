"""jit'd wrapper: GQA-aware flash attention entry point."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


def attention(q, k, v, causal: bool = True, use_kernel: bool = True, interpret: bool = True):
    """q: (B,H,S,D); k,v: (B,KV,S,D) with H % KV == 0 (repeated here)."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, interpret=interpret)
    return mha_ref(q, k, v, causal=causal)
