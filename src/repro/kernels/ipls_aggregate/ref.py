"""Pure-jnp oracle for the fused IPLS aggregation kernel.

Semantics (shared with the kernel and the scalar engine):
``w - eps * masked_SUM(deltas)`` — the 1/r normalization lives in the eps
recursion, never in the reduction, so the update is bitwise comparable
across engines (a mean inside undone by ``eps*r`` outside is not f32-
invertible). Empty masks leave w unchanged (eps * 0 == 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ipls_aggregate_ref(
    w: jax.Array,        # (N,) current partition value
    deltas: jax.Array,   # (R, N) one delta per (potential) contributor
    mask: jax.Array,     # (R,) 1.0 where the contribution arrived
    eps: jax.Array,      # () staleness weight
) -> jax.Array:
    """w - eps * masked_sum(deltas); empty mask leaves w unchanged."""
    mask = mask.astype(jnp.float32)
    agg = jnp.einsum("r,rn->n", mask, deltas.astype(jnp.float32))
    return (w.astype(jnp.float32) - eps.astype(jnp.float32) * agg).astype(w.dtype)


def ipls_aggregate_batched_ref(
    w: jax.Array,        # (K, N) partition values
    deltas: jax.Array,   # (K, R, N) deltas per partition per contributor slot
    mask: jax.Array,     # (K, R) 1.0 where the contribution arrived
    eps: jax.Array,      # (K,) staleness weight per partition
) -> jax.Array:
    """Per-partition ``w - eps * masked_sum(deltas)``; all-zero mask rows
    (zero-contributor rounds, possible under lossy networks) leave their
    partition unchanged. R is whatever the round's contributor table needs —
    the kernel pads it to R_TILE chunks, the oracle takes it as-is."""
    mask = mask.astype(jnp.float32)
    agg = jnp.einsum("kr,krn->kn", mask, deltas.astype(jnp.float32))
    return (w.astype(jnp.float32) - eps.astype(jnp.float32)[:, None] * agg).astype(w.dtype)


def ipls_aggregate_batched_q_ref(
    w: jax.Array,         # (K, N) partition values
    own: jax.Array,       # (K, N) the holder's own (never-quantized) delta
    q: jax.Array,         # (K, R, N) int8 wire codes of remote deltas
    scales: jax.Array,    # (K, R, ceil(N/QBLOCK)) f32 per-block pow2 scales
    mask: jax.Array,      # (K, R) 1.0 where the remote contribution arrived
    own_mask: jax.Array,  # (K,) 1.0 where the holder's own delta participates
    eps: jax.Array,       # (K,) staleness weight per partition
    qblock: int = 1024,
) -> jax.Array:
    """Quantized-input oracle: dequantize (q * scale — exact, scales are
    powers of two or 0) then the same masked-sum update, the raw own-delta
    summed first."""
    K, R, N = q.shape
    nb = scales.shape[2]
    pad = nb * qblock - N
    qb = jnp.pad(q, ((0, 0), (0, 0), (0, pad))).reshape(K, R, nb, qblock)
    deq = qb.astype(jnp.float32) * scales[..., None]
    deq = deq.reshape(K, R, nb * qblock)[..., :N]
    mask = mask.astype(jnp.float32)
    own_mask = own_mask.astype(jnp.float32)
    agg = own_mask[:, None] * own.astype(jnp.float32) + jnp.einsum("kr,krn->kn", mask, deq)
    return (w.astype(jnp.float32) - eps.astype(jnp.float32)[:, None] * agg).astype(w.dtype)
