"""Pure-jnp oracle for the fused IPLS aggregation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ipls_aggregate_ref(
    w: jax.Array,        # (N,) current partition value
    deltas: jax.Array,   # (R, N) one delta per (potential) contributor
    mask: jax.Array,     # (R,) 1.0 where the contribution arrived
    eps: jax.Array,      # () staleness weight
) -> jax.Array:
    """w - eps * masked_mean(deltas); empty mask leaves w unchanged."""
    mask = mask.astype(jnp.float32)
    r = jnp.sum(mask)
    agg = jnp.einsum("r,rn->n", mask, deltas.astype(jnp.float32))
    agg = jnp.where(r > 0, agg / jnp.maximum(r, 1.0), jnp.zeros_like(agg))
    return (w.astype(jnp.float32) - eps.astype(jnp.float32) * agg).astype(w.dtype)


def ipls_aggregate_batched_ref(
    w: jax.Array,        # (K, N) partition values
    deltas: jax.Array,   # (K, R, N) deltas per partition per contributor slot
    mask: jax.Array,     # (K, R) 1.0 where the contribution arrived
    eps: jax.Array,      # (K,) staleness weight per partition
) -> jax.Array:
    """Per-partition ``w - eps * masked_mean(deltas)``; all-zero mask rows
    (zero-contributor rounds, possible under lossy networks) leave their
    partition unchanged. R is whatever the round's contributor table needs —
    the kernel pads it to R_TILE chunks, the oracle takes it as-is."""
    mask = mask.astype(jnp.float32)
    r = jnp.sum(mask, axis=1)
    agg = jnp.einsum("kr,krn->kn", mask, deltas.astype(jnp.float32))
    agg = jnp.where(r[:, None] > 0, agg / jnp.maximum(r, 1.0)[:, None], jnp.zeros_like(agg))
    return (w.astype(jnp.float32) - eps.astype(jnp.float32)[:, None] * agg).astype(w.dtype)
