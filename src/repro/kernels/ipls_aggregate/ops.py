"""jit'd public wrappers for the fused IPLS aggregation kernels."""
from __future__ import annotations


from repro.kernels.ipls_aggregate.ipls_aggregate import (
    ipls_aggregate,
    ipls_aggregate_batched,
    ipls_aggregate_batched_q,
)
from repro.kernels.ipls_aggregate.ref import (
    ipls_aggregate_batched_q_ref,
    ipls_aggregate_batched_ref,
    ipls_aggregate_ref,
)


def aggregate(w, deltas, mask, eps, use_kernel: bool = True, interpret: bool | None = None):
    """Fused w <- w - eps*masked_sum(deltas) (the 1/r lives in the eps
    recursion). interpret=None auto-detects the backend: the TPU kernel body
    runs natively on TPU and through the Pallas interpreter everywhere
    else."""
    if use_kernel:
        return ipls_aggregate(w, deltas, mask, eps, interpret=interpret)
    return ipls_aggregate_ref(w, deltas, mask, eps)


def aggregate_batched(w, deltas, mask, eps, use_kernel: bool = True, interpret: bool | None = None):
    """Partition-batched variant: w (K,N), deltas (K,R,N), mask (K,R),
    eps (K,) — one launch aggregates everything a holder owns."""
    if use_kernel:
        return ipls_aggregate_batched(w, deltas, mask, eps, interpret=interpret)
    return ipls_aggregate_batched_ref(w, deltas, mask, eps)


def aggregate_batched_q(
    w, own, q, scales, mask, own_mask, eps,
    use_kernel: bool = True, interpret: bool | None = None,
):
    """Quantized-wire variant: remote deltas arrive as int8 codes + per-block
    power-of-two scales and dequantize inside the masked-sum reduction; the
    holder's own delta (never on the wire) stays raw f32 and sums first."""
    if use_kernel:
        return ipls_aggregate_batched_q(
            w, own, q, scales, mask, own_mask, eps, interpret=interpret
        )
    return ipls_aggregate_batched_q_ref(w, own, q, scales, mask, own_mask, eps)
