"""jit'd public wrapper for the fused IPLS aggregation kernel."""
from __future__ import annotations

import jax

from repro.kernels.ipls_aggregate.ipls_aggregate import ipls_aggregate
from repro.kernels.ipls_aggregate.ref import ipls_aggregate_ref


def aggregate(w, deltas, mask, eps, use_kernel: bool = True, interpret: bool = True):
    """Fused w <- w - eps*masked_mean(deltas). interpret=True validates the
    TPU kernel body on CPU; on real TPU pass interpret=False."""
    if use_kernel:
        return ipls_aggregate(w, deltas, mask, eps, interpret=interpret)
    return ipls_aggregate_ref(w, deltas, mask, eps)
