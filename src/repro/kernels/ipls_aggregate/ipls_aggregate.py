"""Fused IPLS partition aggregation — Pallas TPU kernel.

One pass over HBM: reads the R replica/contributor deltas tile-by-tile into
VMEM, reduces them with the participation mask, and applies the eps-weighted
update to the partition value. Replaces (R reads + 1 reduce + 1 axpy) XLA
ops with a single fused kernel; on TPU this is HBM-bandwidth-bound, so the
fusion removes R+1 extra round-trips of the partition through HBM.

Two variants:

  * ``ipls_aggregate``       — one partition:  w (N,), deltas (R, N);
  * ``ipls_aggregate_batched`` — all K partitions a holder owns in ONE
    launch: w (K, N), deltas (K, R, N), with a per-partition
    ``[mask(R), r, eps]`` table, grid spanning (K, row-tiles, R-tiles).
    The vectorized round engine flattens every (partition, replica-slot)
    instance of a training round into this layout, so a whole round's
    aggregation is a single kernel call instead of K numpy reductions.
    Rows with an all-zero mask (zero-contributor rounds — possible under
    lossy networks) pass through unchanged.

Tiling: the flat partition is viewed as (rows, 128) lanes; each grid step
owns a (BR, 128) tile (BR=256 rows => 128 KiB f32 per delta in VMEM; with
R<=16 contributors the working set stays ~2 MiB << 16 MiB VMEM). The batched
variant uses BR=128 to cut per-partition padding waste, and tiles the
contributor axis in chunks of R_TILE so variable-r instance tables (lossy
rounds can carry 1 + (A-1) * (1 + max_delay) contributor slots) neither
unroll into huge kernel bodies nor blow the VMEM budget: the grid's last
axis walks R-chunks sequentially and accumulates into the revisited output
block, applying the ``w - eps * masked_mean`` update on the final chunk.

``interpret`` defaults to auto-detection: interpret-mode (CPU emulation of
the kernel body) everywhere except on a real TPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256  # tile rows; lanes fixed at 128
BR_BATCHED = 128  # smaller tile for the partition-batched grid (less padding)
LANES = 128
R_TILE = 8  # contributor-slot chunk per grid step of the batched variant


def default_interpret() -> bool:
    """Run the kernel body via the Pallas interpreter except on real TPUs."""
    return jax.default_backend() != "tpu"


def _kernel(mask_eps_ref, w_ref, deltas_ref, out_ref):
    # mask_eps_ref: (R+2,) SMEM-ish small vector: [mask(R), r_count, eps]
    # w_ref: (BR, 128); deltas_ref: (R, BR, 128)
    me = mask_eps_ref[...]
    R = deltas_ref.shape[0]
    mask = me[:R]
    r_count = me[R]
    eps = me[R + 1]
    acc = jnp.zeros(w_ref.shape, jnp.float32)
    for r in range(R):  # static unroll: R is a compile-time constant
        acc = acc + mask[r] * deltas_ref[r].astype(jnp.float32)
    inv = jnp.where(r_count > 0, 1.0 / jnp.maximum(r_count, 1.0), 0.0)
    out_ref[...] = (w_ref[...].astype(jnp.float32) - eps * acc * inv).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate(w, deltas, mask, eps, interpret: bool | None = None):
    """w: (N,), deltas: (R,N), mask: (R,), eps: (). N padded to BR*128."""
    if interpret is None:
        interpret = default_interpret()
    N = w.shape[0]
    R = deltas.shape[0]
    tile = BR * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, (0, pad))
    dp = jnp.pad(deltas, ((0, 0), (0, pad)))
    rows = (N + pad) // LANES
    w2 = wp.reshape(rows, LANES)
    d2 = dp.reshape(R, rows, LANES)
    grid = (rows // BR,)
    mask_f = mask.astype(jnp.float32)
    me = jnp.concatenate([mask_f, jnp.sum(mask_f)[None], eps.astype(jnp.float32)[None]])

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((R + 2,), lambda i: (0,)),
            pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
            pl.BlockSpec((R, BR, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), w.dtype),
        interpret=interpret,
    )(me, w2, d2)
    return out.reshape(-1)[:N]


def _kernel_batched(table_ref, w_ref, deltas_ref, out_ref):
    # table_ref: (1, Rp+2) per-partition [mask(Rp), r_count, eps]; Rp is the
    # R_TILE-padded contributor count. w_ref: (1, BR_BATCHED, 128);
    # deltas_ref: (1, R_TILE, BR_BATCHED, 128) — one R-chunk per grid step.
    # The grid's last axis walks the R-chunks sequentially, accumulating the
    # masked delta sum into the revisited output block; the final chunk
    # applies w - eps * acc / r.
    rt = pl.program_id(2)
    n_rt = pl.num_programs(2)
    me = table_ref[0]
    Rp = me.shape[0] - 2
    RT = deltas_ref.shape[1]
    mask_blk = jax.lax.dynamic_slice(me, (rt * RT,), (RT,))
    r_count = me[Rp]
    eps = me[Rp + 1]
    acc = jnp.zeros(w_ref.shape[1:], jnp.float32)
    for r in range(RT):  # static unroll of one chunk
        acc = acc + mask_blk[r] * deltas_ref[0, r].astype(jnp.float32)

    @pl.when(rt == 0)
    def _():
        out_ref[0] = acc.astype(out_ref.dtype)

    @pl.when(rt > 0)
    def _():
        out_ref[0] = (out_ref[0].astype(jnp.float32) + acc).astype(out_ref.dtype)

    @pl.when(rt == n_rt - 1)
    def _():
        inv = jnp.where(r_count > 0, 1.0 / jnp.maximum(r_count, 1.0), 0.0)
        out_ref[0] = (
            w_ref[0].astype(jnp.float32) - eps * out_ref[0].astype(jnp.float32) * inv
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate_batched(w, deltas, mask, eps, interpret: bool | None = None):
    """Per-partition masked-mean update for K partitions in one launch.

    w: (K, N), deltas: (K, R, N), mask: (K, R), eps: (K,). Each partition k
    gets ``w[k] - eps[k] * masked_mean(deltas[k], mask[k])``; partitions with
    an all-zero mask row (r = 0) pass through unchanged. R is variable at
    the call site (lossy rounds shrink/grow the contributor table per round)
    and is padded to a multiple of R_TILE with zero mask rows; the grid
    walks R-chunks so large contributor tables neither unroll into huge
    kernel bodies nor exceed VMEM. Partitions of unequal true size share
    the padded N; callers zero-pad tails (the padded lanes compute
    garbage-free zeros since pad(w)=pad(deltas)=0).
    """
    if interpret is None:
        interpret = default_interpret()
    K, N = w.shape
    R = deltas.shape[1]
    rpad = (-R) % R_TILE
    tile = BR_BATCHED * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    dp = jnp.pad(deltas, ((0, 0), (0, rpad), (0, pad)))
    rows = (N + pad) // LANES
    Rp = R + rpad
    w3 = wp.reshape(K, rows, LANES)
    d4 = dp.reshape(K, Rp, rows, LANES)
    mask_f = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, rpad)))
    table = jnp.concatenate(
        [mask_f, jnp.sum(mask_f, axis=1, keepdims=True), eps.astype(jnp.float32)[:, None]],
        axis=1,
    )  # (K, Rp+2)
    grid = (K, rows // BR_BATCHED, Rp // R_TILE)

    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Rp + 2), lambda k, i, rt: (k, 0)),
            pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
            pl.BlockSpec((1, R_TILE, BR_BATCHED, LANES), lambda k, i, rt: (k, rt, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, rows, LANES), w.dtype),
        interpret=interpret,
    )(table, w3, d4)
    return out.reshape(K, -1)[:, :N]
