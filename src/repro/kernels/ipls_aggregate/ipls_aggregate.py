"""Fused IPLS partition aggregation — Pallas TPU kernel.

One pass over HBM: reads the R replica/contributor deltas tile-by-tile into
VMEM, reduces them with the participation mask, and applies the eps-weighted
update to the partition value. Replaces (R reads + 1 reduce + 1 axpy) XLA
ops with a single fused kernel; on TPU this is HBM-bandwidth-bound, so the
fusion removes R+1 extra round-trips of the partition through HBM.

Tiling: the flat partition is viewed as (rows, 128) lanes; each grid step
owns a (BR, 128) tile (BR=256 rows => 128 KiB f32 per delta in VMEM; with
R<=16 contributors the working set stays ~2 MiB << 16 MiB VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256  # tile rows; lanes fixed at 128
LANES = 128


def _kernel(mask_eps_ref, w_ref, deltas_ref, out_ref):
    # mask_eps_ref: (R+2,) SMEM-ish small vector: [mask(R), r_count, eps]
    # w_ref: (BR, 128); deltas_ref: (R, BR, 128)
    me = mask_eps_ref[...]
    R = deltas_ref.shape[0]
    mask = me[:R]
    r_count = me[R]
    eps = me[R + 1]
    acc = jnp.zeros(w_ref.shape, jnp.float32)
    for r in range(R):  # static unroll: R is a compile-time constant
        acc = acc + mask[r] * deltas_ref[r].astype(jnp.float32)
    inv = jnp.where(r_count > 0, 1.0 / jnp.maximum(r_count, 1.0), 0.0)
    out_ref[...] = (w_ref[...].astype(jnp.float32) - eps * acc * inv).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate(w, deltas, mask, eps, interpret: bool = True):
    """w: (N,), deltas: (R,N), mask: (R,), eps: (). N padded to BR*128."""
    N = w.shape[0]
    R = deltas.shape[0]
    tile = BR * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, (0, pad))
    dp = jnp.pad(deltas, ((0, 0), (0, pad)))
    rows = (N + pad) // LANES
    w2 = wp.reshape(rows, LANES)
    d2 = dp.reshape(R, rows, LANES)
    grid = (rows // BR,)
    mask_f = mask.astype(jnp.float32)
    me = jnp.concatenate([mask_f, jnp.sum(mask_f)[None], eps.astype(jnp.float32)[None]])

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((R + 2,), lambda i: (0,)),
            pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
            pl.BlockSpec((R, BR, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), w.dtype),
        interpret=interpret,
    )(me, w2, d2)
    return out.reshape(-1)[:N]
