"""Fused IPLS partition aggregation — Pallas TPU kernel.

One pass over HBM: reads the R replica/contributor deltas tile-by-tile into
VMEM, reduces them with the participation mask, and applies the eps-weighted
update to the partition value. Replaces (R reads + 1 reduce + 1 axpy) XLA
ops with a single fused kernel; on TPU this is HBM-bandwidth-bound, so the
fusion removes R+1 extra round-trips of the partition through HBM.

Semantics are the scalar engine's: ``w - eps * masked_SUM(deltas)``. The
1/r normalization lives entirely in the eps recursion
(``eps <- alpha*eps + (1-alpha)/r``), so the kernel never divides by the
contributor count — that division (a mean inside, undone by ``eps*r`` at the
call site) is not bitwise invertible in f32 and broke engine equivalence at
r=3. Summation is strictly sequential in slot order, within a chunk and
across R_TILE chunks, so the reduction associates exactly like the scalar
oracle's ``np.sum(axis=0)`` over deltas in delivery order. An all-zero mask
row (zero-contributor round) naturally passes w through unchanged.

Three variants:

  * ``ipls_aggregate``       — one partition:  w (N,), deltas (R, N);
  * ``ipls_aggregate_batched`` — all K partitions a holder owns in ONE
    launch: w (K, N), deltas (K, R, N), with a per-partition
    ``[mask(R), eps]`` table, grid spanning (K, row-tiles, R-tiles).
    The vectorized round engine flattens every (partition, replica-slot)
    instance of a training round into this layout, so a whole round's
    aggregation is a single kernel call instead of K numpy reductions.
  * ``ipls_aggregate_batched_q`` — int8-wire variant: remote deltas arrive
    as int8 codes + per-block scales and dequantize INSIDE the reduction;
    the holder's own delta (never on the wire) joins raw, first — matching
    the scalar pending order (local push before inbox drain).

Tiling: the flat partition is viewed as (rows, 128) lanes; each grid step
owns a (BR, 128) tile (BR=256 rows => 128 KiB f32 per delta in VMEM; with
R<=16 contributors the working set stays ~2 MiB << 16 MiB VMEM). The batched
variants use BR=128 to cut per-partition padding waste, and tile the
contributor axis in chunks of R_TILE so variable-r instance tables (lossy
rounds can carry 1 + (A-1) * (1 + max_delay) contributor slots) neither
unroll into huge kernel bodies nor blow the VMEM budget: the grid's last
axis walks R-chunks sequentially, carrying the running sum through the
revisited output block, and applies ``w - eps * acc`` on the final chunk.

``interpret`` defaults to auto-detection: interpret-mode (CPU emulation of
the kernel body) everywhere except on a real TPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256  # tile rows; lanes fixed at 128
BR_BATCHED = 128  # smaller tile for the partition-batched grid (less padding)
LANES = 128
R_TILE = 8  # contributor-slot chunk per grid step of the batched variant
# quantization block of the int8 wire format (must equal kernels/quantize
# BLOCK; asserted in tests — quantize imports default_interpret from here,
# so importing back would be circular). One BR_BATCHED row-tile spans
# exactly BR_BATCHED*LANES/QBLOCK = 16 scale blocks, each 8 row-groups.
QBLOCK = 1024


def default_interpret() -> bool:
    """Run the kernel body via the Pallas interpreter except on real TPUs."""
    return jax.default_backend() != "tpu"


def _kernel(mask_eps_ref, w_ref, deltas_ref, out_ref):
    # mask_eps_ref: (R+1,) SMEM-ish small vector: [mask(R), eps]
    # w_ref: (BR, 128); deltas_ref: (R, BR, 128)
    me = mask_eps_ref[...]
    R = deltas_ref.shape[0]
    mask = me[:R]
    eps = me[R]
    acc = jnp.zeros(w_ref.shape, jnp.float32)
    for r in range(R):  # static unroll: R is a compile-time constant
        acc = acc + mask[r] * deltas_ref[r].astype(jnp.float32)
    out_ref[...] = (w_ref[...].astype(jnp.float32) - eps * acc).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate(w, deltas, mask, eps, interpret: bool | None = None):
    """w: (N,), deltas: (R,N), mask: (R,), eps: (). N padded to BR*128."""
    if interpret is None:
        interpret = default_interpret()
    N = w.shape[0]
    R = deltas.shape[0]
    tile = BR * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, (0, pad))
    dp = jnp.pad(deltas, ((0, 0), (0, pad)))
    rows = (N + pad) // LANES
    w2 = wp.reshape(rows, LANES)
    d2 = dp.reshape(R, rows, LANES)
    grid = (rows // BR,)
    mask_f = mask.astype(jnp.float32)
    me = jnp.concatenate([mask_f, eps.astype(jnp.float32)[None]])

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((R + 1,), lambda i: (0,)),
            pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
            pl.BlockSpec((R, BR, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BR, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), w.dtype),
        interpret=interpret,
    )(me, w2, d2)
    return out.reshape(-1)[:N]


def _kernel_batched(table_ref, w_ref, deltas_ref, out_ref):
    # table_ref: (1, Rp+1) per-partition [mask(Rp), eps]; Rp is the
    # R_TILE-padded contributor count. w_ref: (1, BR_BATCHED, 128);
    # deltas_ref: (1, R_TILE, BR_BATCHED, 128) — one R-chunk per grid step.
    # The grid's last axis walks the R-chunks sequentially; the running sum
    # is carried through the revisited output block so the reduction order
    # is strictly slot 0,1,2,... — bit-identical to the scalar oracle's
    # sequential np.sum (masked-out slots add an exact +0.0).
    rt = pl.program_id(2)
    n_rt = pl.num_programs(2)
    me = table_ref[0]
    Rp = me.shape[0] - 1
    RT = deltas_ref.shape[1]
    mask_blk = jax.lax.dynamic_slice(me, (rt * RT,), (RT,))
    eps = me[Rp]

    @pl.when(rt == 0)
    def _():
        out_ref[0] = jnp.zeros(out_ref.shape[1:], out_ref.dtype)

    acc = out_ref[0].astype(jnp.float32)
    for r in range(RT):  # static unroll of one chunk
        acc = acc + mask_blk[r] * deltas_ref[0, r].astype(jnp.float32)

    @pl.when(rt < n_rt - 1)
    def _():
        out_ref[0] = acc.astype(out_ref.dtype)

    @pl.when(rt == n_rt - 1)
    def _():
        out_ref[0] = (w_ref[0].astype(jnp.float32) - eps * acc).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate_batched(w, deltas, mask, eps, interpret: bool | None = None):
    """Per-partition masked-sum update for K partitions in one launch.

    w: (K, N), deltas: (K, R, N), mask: (K, R), eps: (K,). Each partition k
    gets ``w[k] - eps[k] * sum_r mask[k,r] * deltas[k,r]``; partitions with
    an all-zero mask row (zero-contributor rounds — possible under lossy
    networks) pass through unchanged. R is variable at the call site (lossy
    rounds shrink/grow the contributor table per round) and is padded to a
    multiple of R_TILE with zero mask rows; the grid walks R-chunks so large
    contributor tables neither unroll into huge kernel bodies nor exceed
    VMEM. Partitions of unequal true size share the padded N; callers
    zero-pad tails (the padded lanes compute garbage-free zeros since
    pad(w)=pad(deltas)=0).
    """
    if interpret is None:
        interpret = default_interpret()
    K, N = w.shape
    R = deltas.shape[1]
    rpad = (-R) % R_TILE
    tile = BR_BATCHED * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    dp = jnp.pad(deltas, ((0, 0), (0, rpad), (0, pad)))
    rows = (N + pad) // LANES
    Rp = R + rpad
    w3 = wp.reshape(K, rows, LANES)
    d4 = dp.reshape(K, Rp, rows, LANES)
    mask_f = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, rpad)))
    table = jnp.concatenate([mask_f, eps.astype(jnp.float32)[:, None]], axis=1)  # (K, Rp+1)
    grid = (K, rows // BR_BATCHED, Rp // R_TILE)

    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Rp + 1), lambda k, i, rt: (k, 0)),
            pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
            pl.BlockSpec((1, R_TILE, BR_BATCHED, LANES), lambda k, i, rt: (k, rt, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, rows, LANES), w.dtype),
        interpret=interpret,
    )(table, w3, d4)
    return out.reshape(K, -1)[:, :N]


# Scale blocks spanned by one (BR_BATCHED, LANES) row-tile of the quantized
# variant: 128*128/1024 = 16 per-block scales, each covering 8 row-groups.
SB_TILE = BR_BATCHED * LANES // QBLOCK


def _kernel_batched_q(table_ref, w_ref, own_ref, q_ref, s_ref, out_ref):
    # Quantized contributor rows: deltas arrive as int8 codes q plus per-
    # QBLOCK f32 scales; dequantize (q * scale — exact, scales are powers of
    # two or 0) fuses into the masked-sum accumulation, so the f32 deltas
    # never materialize in HBM. The owner's own delta never crossed the wire
    # and stays raw f32 (own_ref), gated by the own_mask table slot and
    # summed FIRST — the scalar oracle pushes the local delta into pending
    # before draining the inbox, and sum order must match bit for bit.
    # table_ref: (1, Rp+2) = [mask(Rp), own_mask, eps];
    # q_ref: (1, R_TILE, BR_BATCHED, 128) int8;
    # s_ref: (1, R_TILE, SB_TILE) f32 — SB_TILE scale blocks per row-tile.
    rt = pl.program_id(2)
    n_rt = pl.num_programs(2)
    me = table_ref[0]
    Rp = me.shape[0] - 2
    RT = q_ref.shape[1]
    mask_blk = jax.lax.dynamic_slice(me, (rt * RT,), (RT,))
    own_mask = me[Rp]
    eps = me[Rp + 1]
    rows = w_ref.shape[1]
    rows_per_block = QBLOCK // LANES  # 8 contiguous lane-rows share a scale

    @pl.when(rt == 0)
    def _():
        out_ref[0] = (own_mask * own_ref[0].astype(jnp.float32)).astype(out_ref.dtype)

    acc = out_ref[0].astype(jnp.float32)
    for r in range(RT):  # static unroll of one chunk
        s_rows = s_ref[0, r]  # (SB_TILE,)
        s_full = jnp.broadcast_to(
            s_rows[:, None, None], (SB_TILE, rows_per_block, 1)
        ).reshape(rows, 1)
        acc = acc + mask_blk[r] * (q_ref[0, r].astype(jnp.float32) * s_full)

    @pl.when(rt < n_rt - 1)
    def _():
        out_ref[0] = acc.astype(out_ref.dtype)

    @pl.when(rt == n_rt - 1)
    def _():
        out_ref[0] = (w_ref[0].astype(jnp.float32) - eps * acc).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ipls_aggregate_batched_q(
    w, own, q, scales, mask, own_mask, eps, interpret: bool | None = None
):
    """Quantized-input variant of ``ipls_aggregate_batched``.

    w: (K, N) f32; own: (K, N) f32 — the holder's OWN delta (never quantized:
    it doesn't cross the wire); q: (K, R, N) int8 wire codes of the remote
    contributor deltas; scales: (K, R, ceil(N/QBLOCK)) f32 per-block
    power-of-two scales; mask: (K, R) remote-contributor mask; own_mask:
    (K,) 1.0 where the holder's own delta participates; eps: (K,). Computes
    ``w - eps * (own_mask*own + sum_r mask[r]*deq(q[r]))`` with
    deq(q) = q * scale fused into the R_TILE accumulation, own summed first.
    Zero-contributor rows (own_mask and mask all zero) pass through
    unchanged.
    """
    if interpret is None:
        interpret = default_interpret()
    K, N = w.shape
    R = q.shape[1]
    rpad = (-R) % R_TILE
    tile = BR_BATCHED * LANES
    pad = (-N) % tile
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    op = jnp.pad(own, ((0, 0), (0, pad)))
    qp = jnp.pad(q, ((0, 0), (0, rpad), (0, pad)))
    rows = (N + pad) // LANES
    nbp = (N + pad) // QBLOCK  # padded scale-block count (multiple of SB_TILE)
    sp = jnp.pad(
        scales, ((0, 0), (0, rpad), (0, nbp - scales.shape[2]))
    )  # pad blocks carry scale 0 -> dequantize to exact zeros
    Rp = R + rpad
    w3 = wp.reshape(K, rows, LANES)
    o3 = op.reshape(K, rows, LANES)
    q4 = qp.reshape(K, Rp, rows, LANES)
    mask_f = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, rpad)))
    own_f = own_mask.astype(jnp.float32)[:, None]
    table = jnp.concatenate(
        [mask_f, own_f, eps.astype(jnp.float32)[:, None]], axis=1
    )  # (K, Rp+2)
    grid = (K, rows // BR_BATCHED, Rp // R_TILE)

    out = pl.pallas_call(
        _kernel_batched_q,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Rp + 2), lambda k, i, rt: (k, 0)),
            pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
            pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
            pl.BlockSpec((1, R_TILE, BR_BATCHED, LANES), lambda k, i, rt: (k, rt, i, 0)),
            # repro: noqa[PL03] per-block scales: SB_TILE=16 scalars per row-tile
            pl.BlockSpec((1, R_TILE, SB_TILE), lambda k, i, rt: (k, rt, i)),
        ],
        out_specs=pl.BlockSpec((1, BR_BATCHED, LANES), lambda k, i, rt: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, rows, LANES), w.dtype),
        interpret=interpret,
    )(table, w3, o3, q4, sp)
    return out.reshape(K, -1)[:, :N]
