"""Pure-jnp oracle: RWKV6/GLA recurrence, step-by-step (the slow exact form).

    out_t = r_t · S_{t-1} + r_t · (u ⊙ k_t) v_t^T
    S_t   = diag(w_t) · S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, logw, u, init_state=None):
    """r,k,v,logw: (B,T,H,K); u: (H,K). Returns (out (B,T,H,K), S (B,H,K,K))."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((B, H, K, V), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,K)...
        out = jnp.einsum("bhk,bhkv->bhv", rt, S) + jnp.einsum(
            "bhk,hk,bhk,bhv->bhv", rt, u, kt, vt
        )
        S = S * jnp.exp(wt)[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, logw))
    S, outs = jax.lax.scan(step, init_state, xs)
    return jnp.moveaxis(outs, 0, 1), S
