"""jit'd wrapper for the RWKV6/GLA chunked recurrence kernel."""
from __future__ import annotations

from repro.kernels.linear_scan.linear_scan import rwkv6_scan
from repro.kernels.linear_scan.ref import rwkv6_ref


def linear_scan(r, k, v, logw, u, use_kernel: bool = True, interpret: bool = True):
    if use_kernel:
        return rwkv6_scan(r, k, v, logw, u, interpret=interpret)
    return rwkv6_ref(r, k, v, logw, u)
