"""Chunked RWKV6/GLA linear recurrence — Pallas TPU kernel.

The XLA reference path (models/ssm.py) materializes the (Q,Q,K) pairwise
decay tensor per chunk in HBM; this kernel keeps it entirely in VMEM and
carries the (K,V) state in scratch across the sequential chunk grid — one
HBM read of r/k/v/logw and one write of the output per token, which is the
bandwidth lower bound for this operator.

Grid: (B*H, nChunks) sequential. Per-step VMEM: 4 x (Q,K) operands + (Q,Q)
pair buffer per lane-group + (K,V) f32 state ≈ 1.5 MiB at Q=64, K=V=64.

Adaptation note (DESIGN.md §2): the CUDA RWKV kernels parallelize over
(B,H) thread blocks with warp-level time recursion; on TPU the MXU wants
matmul form, so we use the chunked GLA formulation (intra-chunk pairwise +
inter-chunk state carry) — same math, MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q = 64  # chunk length


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sfin_ref, s_scr, *, nc):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)    # (Q,K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)    # (1,K) broadcast row
    S = s_scr[...]                       # (K,V)

    L = jnp.cumsum(lw, axis=0)          # inclusive
    Lx = L - lw                          # exclusive

    # intra-chunk: pairwise per-channel decay (j < i), contracted over K
    diff = Lx[:, None, :] - L[None, :, :]              # (Q,Q,K)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    strict = (ii > jj)[..., None]
    w_pair = jnp.where(strict, jnp.exp(diff), 0.0)     # (Q,Q,K)
    att = jnp.einsum("ik,ijk,jk->ij", r, w_pair, k)    # (Q,Q)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus diagonal
    bon = jnp.sum(r * u * k, axis=1, keepdims=True)    # (Q,1)
    y = y + bon * v
    # carried state
    y = y + jax.lax.dot_general(r * jnp.exp(Lx), S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    # state update
    last = L[-1:, :]                                    # (1,K)
    S_new = S * jnp.exp(last).T + jax.lax.dot_general(
        (k * jnp.exp(last - L)), v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s_scr[...] = S_new

    @pl.when(ci == nc - 1)
    def _emit():
        sfin_ref[0] = S_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, logw, u, interpret: bool = True):
    """r,k,v,logw: (B,T,H,K) with T % 64 == 0; u: (H,K).
    Returns (out (B,T,H,K), final_state (B,H,K,V))."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    assert T % Q == 0, (T,)
    nc = T // Q

    def fold(a):  # (B,T,H,Kv) -> (B*H, T, Kv)
        return jnp.moveaxis(a, 2, 1).reshape(B * H, T, a.shape[-1])

    rf, kf, vf, lwf = fold(r), fold(k), fold(v), fold(logw)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    out, sfin = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, K), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    out = jnp.moveaxis(out.reshape(B, H, T, V), 1, 2)
    return out, sfin.reshape(B, H, K, V)
