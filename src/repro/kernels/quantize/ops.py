"""jit'd wrapper for int8 gradient compression with error feedback."""
from __future__ import annotations

from repro.kernels.quantize.quantize import dequantize, quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


def compress(x, err, use_kernel: bool = True, interpret: bool | None = None):
    """interpret=None auto-detects the backend (native on TPU, Pallas
    interpreter elsewhere), same policy as ``kernels/ipls_aggregate``."""
    if use_kernel:
        return quantize(x, err, interpret=interpret)
    return quantize_ref(x, err)


def decompress(q, scales, use_kernel: bool = True, interpret: bool | None = None):
    if use_kernel:
        return dequantize(q, scales, interpret=interpret)
    return dequantize_ref(q, scales)
