"""Pure-jnp oracle: block-wise int8 quantization with error feedback.

The compressed-UpdateModel path: IPLS agents on WAN links (paper setting)
and compressed reduce-scatter at pod scale both send int8 deltas; the error
feedback accumulator keeps the quantization noise from biasing convergence
(Karimireddy et al., arXiv:1901.09847).

Scales are exact powers of two (see ``core/wire.py``): every codec op is
exact in f32, so this reference, the Pallas kernel, and the numpy wire codec
produce identical bits from identical inputs.

Wire contract (shared with ``quantize.py`` and ``core/wire.py``): N values
become N int8 codes plus ``ceil(N / BLOCK)`` f32 per-block scales. Inputs of
any N are zero-padded to whole blocks internally and trimmed back.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024
_EMIN = 6


def _pow2_scales(absmax):
    bits = jax.lax.bitcast_convert_type(absmax, jnp.int32)
    e0 = bits >> 23
    zero = e0 <= _EMIN
    e0c = jnp.maximum(e0, _EMIN + 1)
    scale = jax.lax.bitcast_convert_type((e0c - _EMIN) << 23, jnp.float32)
    inv = jax.lax.bitcast_convert_type(((127 + 133) - e0c) << 23, jnp.float32)
    return jnp.where(zero, 0.0, scale), jnp.where(zero, 0.0, inv)


def quantize_ref(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x, err: (N,), any N. Returns (q (N,) int8, scales (ceil(N/BLOCK),),
    new_err (N,))."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xb = (jnp.pad(x, (0, pad)) + jnp.pad(err, (0, pad)))
    xb = xb.reshape(-1, BLOCK).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale, inv = _pow2_scales(absmax)
    q = jnp.clip(jnp.round(xb * inv), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = (xb - deq).reshape(-1)
    return q.reshape(-1)[:n], scale[:, 0], new_err[:n]


def dequantize_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    n = q.shape[0]
    pad = (-n) % BLOCK
    qb = jnp.pad(q, (0, pad)).reshape(-1, BLOCK).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(-1)[:n]
