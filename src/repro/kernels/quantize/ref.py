"""Pure-jnp oracle: block-wise int8 quantization with error feedback.

The compressed-UpdateModel path: IPLS agents on WAN links (paper setting)
and compressed reduce-scatter at pod scale both send int8 deltas; the error
feedback accumulator keeps the quantization noise from biasing convergence
(Karimireddy et al., arXiv:1901.09847).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def quantize_ref(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x, err: (N,) with N % BLOCK == 0. Returns (q int8, scales, new_err)."""
    n = x.shape[0]
    xb = (x + err).reshape(n // BLOCK, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * safe
    new_err = (xb - deq).reshape(-1)
    return q.reshape(-1), scale[:, 0], new_err


def dequantize_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    n = q.shape[0]
    qb = q.reshape(n // BLOCK, BLOCK).astype(jnp.float32)
    return (qb * jnp.maximum(scales[:, None], 1e-12)).reshape(-1)
