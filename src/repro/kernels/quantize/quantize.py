"""Block-int8 quantize with error feedback — Pallas TPU kernel.

Fuses (add error) -> (blockwise absmax) -> (scale/round/clip) -> (residual)
into one VMEM pass; the XLA path round-trips x through HBM four times.
Tile: 8 blocks of 1024 = (8, 1024) per grid step (32 KiB f32).

Scales are exact powers of two, picked by exponent arithmetic on the absmax
bit pattern (see ``core/wire.py`` for the rationale): every codec op is then
exact in f32, so kernel, jnp reference, and numpy wire codec agree bit for
bit in every compilation context. Blocks with absmax below 2**-120
(including all-zero blocks) carry scale 0 and all-zero codes.

Wire contract (shared with ``ref.py`` and ``core/wire.py``): a vector of N
values quantizes into N int8 codes plus ``ceil(N / BLOCK)`` f32 per-block
scales; both entry points pad to their tile internally and trim the outputs
back, so any N is accepted.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ipls_aggregate.ipls_aggregate import default_interpret

BLOCK = 1024
TILE = 8  # blocks per grid step
_EMIN = 6  # biased exponents <= this quantize to the zero block


def num_blocks(n: int) -> int:
    """Per-block scale count for an n-element payload: ceil(n / BLOCK)."""
    return -(-n // BLOCK)


def _pow2_scales(absmax):
    """(scale, inv_scale), both exact powers of two: scale = 2**(E-6) puts
    absmax/scale in [64, 128)."""
    bits = jax.lax.bitcast_convert_type(absmax, jnp.int32)
    e0 = bits >> 23
    zero = e0 <= _EMIN
    e0c = jnp.maximum(e0, _EMIN + 1)
    scale = jax.lax.bitcast_convert_type((e0c - _EMIN) << 23, jnp.float32)
    inv = jax.lax.bitcast_convert_type(((127 + 133) - e0c) << 23, jnp.float32)
    return jnp.where(zero, 0.0, scale), jnp.where(zero, 0.0, inv)


def _kernel(x_ref, e_ref, q_ref, s_ref, ne_ref):
    x = x_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)  # (TILE, BLOCK)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale, inv = _pow2_scales(absmax)
    q = jnp.clip(jnp.round(x * inv), -127, 127)
    deq = q * scale
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    ne_ref[...] = (x - deq).astype(ne_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, err, interpret: bool | None = None):
    """x, err: (N,), any N. Returns (q (N,) int8, scales (ceil(N/BLOCK),),
    new_err (N,)); padding to TILE*BLOCK is internal and trimmed back."""
    if interpret is None:
        interpret = default_interpret()
    N = x.shape[0]
    pad = (-N) % (TILE * BLOCK)
    xp = jnp.pad(x, (0, pad))
    ep = jnp.pad(err, (0, pad))
    nb = (N + pad) // BLOCK
    x2 = xp.reshape(nb, BLOCK)
    e2 = ep.reshape(nb, BLOCK)
    grid = (nb // TILE,)
    q, s, ne = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            # repro: noqa[PL03] TILE=8 rows/block is the public scales layout;
            # the int8 payload tolerates the (8,1024) tile in interpret mode
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            # repro: noqa[PL03] per-block scalar scale: (TILE,1) is the shape
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, BLOCK), x.dtype),
        ],
        interpret=interpret,
    )(x2, e2)
    return q.reshape(-1)[:N], s[: num_blocks(N), 0], ne.reshape(-1)[:N]


def _dq_kernel(q_ref, s_ref, o_ref):
    # scales are exact powers of two (or 0 for zero blocks): a plain multiply
    # reconstructs the dequantized value exactly
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(q, scales, interpret: bool | None = None):
    """q: (N,) int8, scales: (ceil(N/BLOCK),). Any N: the payload is padded
    to a whole TILE of blocks (pad blocks carry zero codes and zero scales,
    which dequantize to exact zeros) and the output trimmed back to N —
    mirroring ``quantize``'s pad/trim path, so a quantize->dequantize round
    trip works at every shape edge (N % BLOCK != 0, nb % TILE != 0)."""
    if interpret is None:
        interpret = default_interpret()
    N = q.shape[0]
    pad = (-N) % (TILE * BLOCK)
    nb = (N + pad) // BLOCK
    qp = jnp.pad(q, (0, pad)).reshape(nb, BLOCK)
    sp = jnp.pad(scales, (0, nb - scales.shape[0])).reshape(nb, 1)
    grid = (nb // TILE,)
    out = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            # repro: noqa[PL03] per-block scalar scale: (TILE,1) is the shape
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return out.reshape(-1)[:N]
