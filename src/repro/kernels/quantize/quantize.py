"""Block-int8 quantize with error feedback — Pallas TPU kernel.

Fuses (add error) -> (blockwise absmax) -> (scale/round/clip) -> (residual)
into one VMEM pass; the XLA path round-trips x through HBM four times.
Tile: 8 blocks of 1024 = (8, 1024) per grid step (32 KiB f32)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
TILE = 8  # blocks per grid step


def _kernel(x_ref, e_ref, q_ref, s_ref, ne_ref):
    x = x_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)  # (TILE, BLOCK)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / safe), -127, 127)
    deq = q * safe
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    ne_ref[...] = (x - deq).astype(ne_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, err, interpret: bool = True):
    """x, err: (N,), N % (TILE*BLOCK) == 0 after padding (handled here)."""
    N = x.shape[0]
    pad = (-N) % (TILE * BLOCK)
    xp = jnp.pad(x, (0, pad))
    ep = jnp.pad(err, (0, pad))
    nb = (N + pad) // BLOCK
    x2 = xp.reshape(nb, BLOCK)
    e2 = ep.reshape(nb, BLOCK)
    grid = (nb // TILE,)
    q, s, ne = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            # repro: noqa[PL03] TILE=8 rows/block is the public scales layout;
            # the int8 payload tolerates the (8,1024) tile in interpret mode
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            # repro: noqa[PL03] per-block scalar scale: (TILE,1) is the shape
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, BLOCK), x.dtype),
        ],
        interpret=interpret,
    )(x2, e2)
    return q.reshape(-1)[:N], s[:, 0], ne.reshape(-1)[:N]


def _dq_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * jnp.maximum(s_ref[...], 1e-12)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(q, scales, interpret: bool = True):
    N = q.shape[0]
    nb = N // BLOCK
    grid = (max(nb // TILE, 1),)
    out = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
            # repro: noqa[PL03] per-block scalar scale: (TILE,1) is the shape
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        interpret=interpret,
    )(q.reshape(nb, BLOCK), scales.reshape(nb, 1))
    return out.reshape(-1)
