"""Pure-jnp oracle: one-token decode attention against a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_ref(q, k, v, pos):
    """q: (B,H,D) one new token; k,v: (B,H,S,D) cache; pos: () number of
    valid positions (0..pos inclusive are attended)."""
    S = k.shape[2]
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bhd,bhtd->bht", q, k).astype(jnp.float32) * scale
    valid = jnp.arange(S)[None, None, :] <= pos
    logits = jnp.where(valid, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", probs.astype(q.dtype), v)
