"""jit'd wrapper for flash-decode (GQA repeat handled here)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_ref


def decode(q, k, v, pos, use_kernel: bool = True, interpret: bool = True):
    """q: (B,H,D); k,v: (B,KV,S,D)."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if use_kernel:
        return decode_attention(q, k, v, pos, interpret=interpret)
    return decode_ref(q, k, v, pos)
