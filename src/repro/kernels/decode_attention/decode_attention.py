"""Flash-decode — Pallas TPU kernel (FlashDecoding, arXiv:2311.01282 idea
adapted to TPU: the KV cache is split into sequence blocks; partial softmax
statistics accumulate in VMEM scratch across the sequential grid).

This kernel is the single-chip building block of the CONTEXT-PARALLEL decode
path: across chips the cache is sharded over "model"/("data","model") and the
(num, denom) pairs combine with one tiny all-reduce; within a chip this
kernel streams the local S/BS blocks through VMEM.

Grid: (B, H, nS). Valid-length masking comes from the ``pos`` scalar (SMEM).
Block: (BS=256, D) keys/values — 128 KiB per operand at D=128, f32 acc in
scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 256
NEG_INF = float(np.finfo(np.float32).min)


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, ns):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    block_start = si * BS

    @pl.when(block_start <= pos)
    def _run():
        q = q_ref[0, 0].astype(jnp.float32)          # (1, D) kept 2D
        k = k_ref[0, 0].astype(jnp.float32)          # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (1, BS)
        idx = block_start + jax.lax.broadcasted_iota(jnp.int32, (1, BS), 1)
        logits = jnp.where(idx <= pos, logits, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, pos, interpret: bool = True):
    """q: (B,H,D); k,v: (B,H,S,D), S % 256 == 0; pos: () int32."""
    B, H, D = q.shape
    S = k.shape[2]
    assert S % BS == 0, (S,)
    ns = S // BS
    scale = 1.0 / np.sqrt(D)
    q4 = q.reshape(B, H, 1, D)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, ns=ns),
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, BS, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, BS, D), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q4, k, v)
    return out.reshape(B, H, D)
