"""Checkpoint/restore for fault tolerance (msgpack + raw buffers; no orbax in
this container).

Design for the 1000-node regime:
  * Partition-aware: each IPLS partition owner ("data" rank) can write ONLY
    its owned shard (``shard_id``/``num_shards``), so checkpoint bandwidth
    scales out with the fleet instead of funnelling through one host — the
    checkpoint plane mirrors the paper's Terminate() upload, where a leaving
    agent persists exactly its own partitions to IPFS.
  * Atomic: write to <dir>.tmp then rename; a crash mid-write never corrupts
    the latest complete checkpoint.
  * Async-friendly: ``CheckpointManager.save_async`` hands the host copy to a
    background thread (device->host transfer happens before returning, so the
    training step can continue mutating device buffers).
  * Self-describing: dtype/shape/tree structure embedded; restore validates
    against the expected tree when given.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(
    directory: str,
    tree: Any,
    step: int,
    shard_id: int = 0,
    num_shards: int = 1,
) -> str:
    """Write one shard of a checkpoint. Returns the final directory path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{shard_id}"
    os.makedirs(tmp, exist_ok=True)
    named = _flatten_with_paths(tree)
    index: Dict[str, Any] = {"step": step, "num_shards": num_shards, "arrays": {}}
    blob_path = os.path.join(tmp, f"shard_{shard_id}.bin")
    with open(blob_path, "wb") as f:
        off = 0
        for name, leaf in sorted(named.items()):
            arr = np.asarray(leaf)
            data = arr.tobytes()
            index["arrays"][name] = {
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "offset": off,
                "nbytes": len(data),
            }
            f.write(data)
            off += len(data)
    with open(os.path.join(tmp, f"index_{shard_id}.json"), "w") as f:
        json.dump(index, f)
    # atomic publish: first shard creates the final dir; others move in
    os.makedirs(final, exist_ok=True)
    for fname in os.listdir(tmp):
        os.replace(os.path.join(tmp, fname), os.path.join(final, fname))
    shutil.rmtree(tmp, ignore_errors=True)
    # completion marker per shard
    with open(os.path.join(final, f"COMMITTED_{shard_id}"), "w") as f:
        f.write("ok")
    return final


def _is_complete(path: str, num_shards: int) -> bool:
    return all(
        os.path.exists(os.path.join(path, f"COMMITTED_{s}")) for s in range(num_shards)
    )


def latest_step(directory: str, num_shards: int = 1) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(directory, name)
            try:
                s = int(name.split("_")[1].split(".")[0])
            except ValueError:
                continue
            if _is_complete(full, num_shards):
                steps.append(s)
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    like: Any,
    step: Optional[int] = None,
    shard_id: int = 0,
    num_shards: int = 1,
) -> tuple[Any, int]:
    """Restore the (shard of the) tree. ``like`` supplies structure; leaves
    are replaced by the stored arrays (validated for shape/dtype)."""
    if step is None:
        step = latest_step(directory, num_shards)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, f"index_{shard_id}.json")) as f:
        index = json.load(f)
    blob = open(os.path.join(final, f"shard_{shard_id}.bin"), "rb").read()
    named = _flatten_with_paths(like)
    out: Dict[str, np.ndarray] = {}
    for name, meta in index["arrays"].items():
        arr = np.frombuffer(
            blob, dtype=np.dtype(meta["dtype"]), count=int(np.prod(meta["shape"])) if meta["shape"] else 1,
            offset=meta["offset"],
        ).reshape(meta["shape"])
        out[name] = arr
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)
    flat, treedef = leaves_with_paths
    new_leaves = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if name not in out:
            raise KeyError(f"checkpoint missing array {name}")
        stored = out[name]
        want_shape = tuple(leaf.shape) if hasattr(leaf, "shape") else None
        if want_shape is not None and tuple(stored.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {stored.shape} != expected {want_shape}")
        new_leaves.append(stored)
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), new_leaves)
    return tree, step


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async save."""

    def __init__(self, directory: str, keep: int = 3, num_shards: int = 1):
        self.directory = directory
        self.keep = keep
        self.num_shards = num_shards
        self._thread: Optional[threading.Thread] = None

    def save(self, tree, step: int, shard_id: int = 0) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # device->host now
        save_checkpoint(self.directory, host_tree, step, shard_id, self.num_shards)
        self._gc()

    def save_async(self, tree, step: int, shard_id: int = 0) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # copy BEFORE returning
        self.wait()
        self._thread = threading.Thread(
            target=lambda: (
                save_checkpoint(self.directory, host_tree, step, shard_id, self.num_shards),
                self._gc(),
            ),
            daemon=True,
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like, shard_id: int = 0):
        return restore_checkpoint(
            self.directory, like, None, shard_id, self.num_shards
        )

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and "." not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
