"""granite-moe-3b-a800m [hf:ibm-granite]: 32L, d_model 1536, 24 heads / 8 kv
(GQA, head_dim 64), MoE: 40 experts, top-8, d_expert 512 (SwiGLU), vocab
49155, tied embeddings.

Sharding note: 40 experts do not divide the 16-way model axis, so experts are
replicated and the EXPERT FFN dim (512 = 16*32) is tensor-parallel instead —
set via sharding_overrides (the per-arch escape hatch of the logical-axis
system)."""
from repro.configs.base import attn_block, moe_block
from repro.models.transformer import ArchConfig, GroupSpec

D, H, KV, HD, V = 1536, 24, 8, 64, 49155
E, K, DE = 40, 8, 512


def config() -> ArchConfig:
    layer = (
        attn_block(D, H, KV, HD),
        moe_block(D, DE, E, K, capacity_factor=1.25),
    )
    return ArchConfig(
        name="granite-moe-3b-a800m",
        vocab=V,
        d_model=D,
        groups=(GroupSpec(blocks=layer, repeat=32),),
        tie_embeddings=True,
        sharding_overrides={"experts": None, "expert_ffn": "model"},
    )


def reduced() -> ArchConfig:
    layer = (
        attn_block(64, 4, 2, 16),
        moe_block(64, 32, 8, 2, capacity_factor=2.0),
    )
    return ArchConfig(
        name="granite-moe-reduced",
        vocab=256,
        d_model=64,
        groups=(GroupSpec(blocks=layer, repeat=2),),
        tie_embeddings=True,
        sharding_overrides={"experts": None, "expert_ffn": "model"},
    )
