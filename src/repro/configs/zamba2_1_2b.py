"""zamba2-1.2b [arXiv:2411.15242]: 38 Mamba2 layers (d_model 2048, ssm_state
64, head_dim 64) + ONE shared attention(32H, MHA)+MLP(8192) block applied
every 6 Mamba layers with shared weights (the Zamba recipe), vocab 32000,
tied embeddings. Hybrid => subquadratic, runs long_500k."""
from repro.configs.base import attn_block, mamba2_block, mlp_block
from repro.models.transformer import ArchConfig, GroupSpec

D, V = 2048, 32000


def config() -> ArchConfig:
    mamba = mamba2_block(D, d_state=64)
    shared = (attn_block(D, 32, 32, 64), mlp_block(D, 8192))
    return ArchConfig(
        name="zamba2-1.2b",
        vocab=V,
        d_model=D,
        groups=(
            GroupSpec(blocks=(mamba,) * 6, repeat=6, shared=shared),  # 36 mamba + 6 shared apps
            GroupSpec(blocks=(mamba, mamba), repeat=1),               # 38 total mamba layers
        ),
        tie_embeddings=True,
        subquadratic=True,
    )


def reduced() -> ArchConfig:
    mamba = mamba2_block(64, d_state=16, chunk=16)
    shared = (attn_block(64, 4, 4, 16), mlp_block(64, 128))
    return ArchConfig(
        name="zamba2-reduced",
        vocab=256,
        d_model=64,
        groups=(
            GroupSpec(blocks=(mamba,) * 2, repeat=2, shared=shared),
            GroupSpec(blocks=(mamba,), repeat=1),
        ),
        tie_embeddings=True,
        subquadratic=True,
    )
