"""Architecture registry: ``--arch <id>`` resolution, model construction,
shape table, and input_specs (ShapeDtypeStruct stand-ins, no allocation)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerLM
from repro.models.whisper import WhisperConfig, WhisperModel

ARCH_MODULES = {
    "gemma3-1b": "repro.configs.gemma3_1b",
    "minitron-4b": "repro.configs.minitron_4b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
}

ARCH_IDS = tuple(ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str, reduced: bool = False):
    mod = importlib.import_module(ARCH_MODULES[arch])
    return mod.reduced() if reduced else mod.config()


def build_model(arch_or_cfg):
    cfg = get_config(arch_or_cfg) if isinstance(arch_or_cfg, str) else arch_or_cfg
    if isinstance(cfg, WhisperConfig):
        return WhisperModel(cfg)
    return TransformerLM(cfg)


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN.md §5)."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; pure full-attention arch"
    return True, ""


def input_specs(cfg, shape: ShapeSpec, reduced_scale: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the given shape.

    For train: the full federated batch (tokens + participation mask).
    For prefill: the request batch. For decode: one new token + position.
    ``reduced_scale`` shrinks seq/batch for CPU smoke testing.
    """
    S, B = shape.seq_len, shape.global_batch
    if reduced_scale:
        S, B = max(S // reduced_scale, 8), max(B // reduced_scale, 1)
    i32 = jnp.int32
    is_whisper = isinstance(cfg, WhisperConfig)
    if shape.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "participation": jax.ShapeDtypeStruct((B,), jnp.float32),
        }
        if is_whisper:
            specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if getattr(cfg, "mrope", False):
            specs["positions3"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if is_whisper:
            specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if getattr(cfg, "mrope", False):
            specs["positions3"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs
    # decode: one token against a cache of S
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
