"""qwen2-vl-72b [arXiv:2409.12191]: 80L, d_model 8192, 64 heads / 8 kv (GQA,
head_dim 128), d_ff 29568 (SwiGLU), vocab 152064, M-RoPE (sections 16/24/24
freq pairs for t/h/w), qkv bias, untied embeddings. Vision frontend is a
STUB: input_specs supplies token ids + precomputed (3,B,S) M-RoPE position
ids (dynamic-resolution patching happens upstream)."""
from repro.configs.base import dense_lm
from repro.models.transformer import ArchConfig


def config() -> ArchConfig:
    return dense_lm(
        "qwen2-vl-72b",
        n_layers=80, d_model=8192, n_heads=64, kv_heads=8, d_ff=29568,
        vocab=152064, head_dim=128, activation="silu",
        rope_theta=1000000.0, tie_embeddings=False, bias=True, mrope=True,
    )


def reduced() -> ArchConfig:
    return dense_lm(
        "qwen2-vl-reduced",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, bias=True, mrope=True, mrope_sections=(2, 3, 3),
    )
