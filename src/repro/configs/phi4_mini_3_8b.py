"""phi4-mini-3.8b [arXiv:2412.08905]: 32L, d_model 3072, 24 heads / 8 kv
(GQA), head_dim 128, d_ff 8192 (SwiGLU), vocab 200064, tied embeddings."""
from repro.configs.base import dense_lm
from repro.models.transformer import ArchConfig


def config() -> ArchConfig:
    return dense_lm(
        "phi4-mini-3.8b",
        n_layers=32, d_model=3072, n_heads=24, kv_heads=8, d_ff=8192,
        vocab=200064, head_dim=128, activation="silu",
        rope_theta=10000.0, tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dense_lm(
        "phi4-mini-reduced",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, tie_embeddings=True,
    )
