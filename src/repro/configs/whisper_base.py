"""whisper-base [arXiv:2212.04356]: 6L encoder + 6L decoder, d_model 512,
8 heads (MHA), d_ff 2048 (GELU), vocab 51865, enc-dec with conv frontend
STUBBED (input_specs provides precomputed frame embeddings)."""
from repro.models.whisper import WhisperConfig


def config() -> WhisperConfig:
    return WhisperConfig(
        name="whisper-base",
        vocab=51865, d_model=512, n_heads=8, kv_heads=8, d_ff=2048,
        enc_layers=6, dec_layers=6, max_positions=65536,
    )


def reduced() -> WhisperConfig:
    return WhisperConfig(
        name="whisper-reduced",
        vocab=256, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        enc_layers=2, dec_layers=2, max_positions=128,
    )
