"""minitron-4b [arXiv:2407.14679]: pruned Nemotron. 32L, d_model 3072,
24 heads / 8 kv (GQA), head_dim 128, d_ff 9216 with squared-ReLU (non-gated,
the Nemotron recipe), vocab 256000, untied embeddings."""
from repro.configs.base import dense_lm
from repro.models.transformer import ArchConfig


def config() -> ArchConfig:
    return dense_lm(
        "minitron-4b",
        n_layers=32, d_model=3072, n_heads=24, kv_heads=8, d_ff=9216,
        vocab=256000, head_dim=128, activation="relu2", gated=False,
        rope_theta=10000.0, tie_embeddings=False,
    )


def reduced() -> ArchConfig:
    return dense_lm(
        "minitron-reduced",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, activation="relu2", gated=False,
    )
