from repro.configs.registry import (
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    build_model,
    get_config,
    input_specs,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "build_model",
    "get_config",
    "input_specs",
    "shape_applicable",
]
