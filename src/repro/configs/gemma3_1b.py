"""gemma3-1b [hf:google/gemma-3-1b-pt]: 26L, d_model 1152, 4 q heads / 1 kv
head (MQA), head_dim 256, d_ff 6912 (GeGLU), vocab 262144. 5:1
local(sliding-512):global layer pattern; local layers rope theta 10k, global
1M (128k context recipe). Tied embeddings, embed scaling, qk-norm."""
from repro.configs.base import attn_block, mlp_block
from repro.models.transformer import ArchConfig, GroupSpec

D, H, KV, HD, FF, V = 1152, 4, 1, 256, 6912, 262144
WINDOW = 512


def _layer(local: bool, d=D, h=H, kv=KV, hd=HD, ff=FF, window=WINDOW):
    attn = attn_block(
        d, h, kv, hd,
        window=window if local else None,
        rope_theta=10000.0 if local else 1000000.0,
        qk_norm=True,
    )
    return (attn, mlp_block(d, ff, "gelu"))


def config() -> ArchConfig:
    blocks = ()
    for _ in range(5):
        blocks += _layer(True)
    blocks += _layer(False)
    tail = _layer(True) + _layer(True)
    return ArchConfig(
        name="gemma3-1b",
        vocab=V,
        d_model=D,
        groups=(
            GroupSpec(blocks=blocks, repeat=4),   # 4 x (5 local + 1 global) = 24
            GroupSpec(blocks=tail, repeat=1),     # + 2 local = 26 layers
        ),
        tie_embeddings=True,
        embed_scale=True,
        subquadratic=True,  # local layers dominate; global-layer decode is O(S)
    )


def reduced() -> ArchConfig:
    """Smoke-test config: same family (5:1 local:global, MQA, tied, scaled)."""
    d, h, kv, hd, ff, v, w = 64, 4, 1, 16, 128, 256, 8
    blocks = ()
    for _ in range(2):
        blocks += (
            attn_block(d, h, kv, hd, window=w, qk_norm=True),
            mlp_block(d, ff, "gelu"),
        )
    blocks += (attn_block(d, h, kv, hd, qk_norm=True), mlp_block(d, ff, "gelu"))
    return ArchConfig(
        name="gemma3-reduced",
        vocab=v,
        d_model=d,
        groups=(GroupSpec(blocks=blocks, repeat=2),),
        tie_embeddings=True,
        embed_scale=True,
        subquadratic=True,
    )
