"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L, d_model 2048, 16 heads with
MLA (kv_lora 512, qk_nope 128, qk_rope 64, v_head 128). Layer 0 is dense
(d_ff 10944); layers 1-26 are MoE: 64 routed experts top-6 + 2 shared
experts, d_expert 1408 (SwiGLU). vocab 102400."""

from repro.configs.base import mlp_block, moe_block
from repro.models import layers as L
from repro.models.transformer import ArchConfig, BlockSpec, GroupSpec

D, H, V = 2048, 16, 102400
KV_LORA, QK_NOPE, QK_ROPE, V_HEAD = 512, 128, 64, 128
E, K, DE = 64, 6, 1408


def mla_block(d=D, h=H) -> BlockSpec:
    return BlockSpec(
        kind="mla",
        mla=L.MLASpec(
            d_model=d, n_heads=h, kv_lora=KV_LORA,
            qk_nope=QK_NOPE, qk_rope=QK_ROPE, v_head=V_HEAD,
        ),
    )


def config() -> ArchConfig:
    dense_layer = (mla_block(), mlp_block(D, 10944))
    moe_layer = (
        mla_block(),
        moe_block(D, DE, E, K, num_shared=2, d_shared=2 * DE, capacity_factor=1.25),
    )
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        vocab=V,
        d_model=D,
        groups=(
            GroupSpec(blocks=dense_layer, repeat=1),
            GroupSpec(blocks=moe_layer, repeat=26),
        ),
        tie_embeddings=False,
    )


def reduced() -> ArchConfig:
    mla = BlockSpec(
        kind="mla",
        mla=L.MLASpec(d_model=64, n_heads=4, kv_lora=32, qk_nope=16, qk_rope=8, v_head=16),
    )
    dense_layer = (mla, mlp_block(64, 128))
    moe_layer = (mla, moe_block(64, 32, 8, 2, num_shared=2, d_shared=64, capacity_factor=2.0))
    return ArchConfig(
        name="deepseek-v2-lite-reduced",
        vocab=256,
        d_model=64,
        groups=(
            GroupSpec(blocks=dense_layer, repeat=1),
            GroupSpec(blocks=moe_layer, repeat=2),
        ),
    )
