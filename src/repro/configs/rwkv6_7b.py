"""rwkv6-7b "Finch" [arXiv:2404.05892]: 32L, d_model 4096 (64 heads of 64),
attention-free data-dependent-decay linear recurrence (time mix) + squared-
ReLU channel mix with d_ff 14336, vocab 65536, untied. Fully sub-quadratic:
runs long_500k with an O(1)-per-token state."""
from repro.configs.base import rwkv6_blocks
from repro.models.transformer import ArchConfig, GroupSpec


def config() -> ArchConfig:
    # chunk=16: the pairwise-decay bytes scale as T*Q*H*K while the carried-
    # state bytes scale as (T/Q)*H*K*V; Q* = sqrt(V) = 8-16 minimizes the sum
    # (see EXPERIMENTS.md §Perf rwkv6 iteration log)
    time_mix, channel_mix = rwkv6_blocks(4096, 14336, chunk=16)
    return ArchConfig(
        name="rwkv6-7b",
        vocab=65536,
        d_model=4096,
        groups=(GroupSpec(blocks=(time_mix, channel_mix), repeat=32),),
        tie_embeddings=False,
        subquadratic=True,
    )


def reduced() -> ArchConfig:
    time_mix, channel_mix = rwkv6_blocks(64, 128, chunk=8)
    return ArchConfig(
        name="rwkv6-reduced",
        vocab=256,
        d_model=64,
        groups=(GroupSpec(blocks=(time_mix, channel_mix), repeat=2),),
        subquadratic=True,
    )
