"""Config helpers shared by the per-architecture files."""
from __future__ import annotations

from typing import Optional, Tuple

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import ArchConfig, BlockSpec, GroupSpec


def attn_block(
    d_model: int,
    n_heads: int,
    kv_heads: int,
    head_dim: int,
    window: Optional[int] = None,
    rope: str = "std",
    rope_theta: float = 10000.0,
    qk_norm: bool = False,
    bias: bool = False,
    mrope_sections: Tuple[int, int, int] = (16, 24, 24),
) -> BlockSpec:
    return BlockSpec(
        kind="attn",
        attn=L.AttnSpec(
            d_model=d_model,
            n_heads=n_heads,
            kv_heads=kv_heads,
            head_dim=head_dim,
            window=window,
            rope=rope,
            rope_theta=rope_theta,
            qk_norm=qk_norm,
            bias=bias,
            mrope_sections=mrope_sections,
        ),
    )


def mlp_block(d_model: int, d_ff: int, activation: str = "silu", gated: bool = True) -> BlockSpec:
    return BlockSpec(kind="mlp", mlp=L.MLPSpec(d_model, d_ff, activation, gated))


def moe_block(
    d_model: int,
    d_expert: int,
    num_experts: int,
    top_k: int,
    num_shared: int = 0,
    d_shared: int = 0,
    capacity_factor: float = 1.25,
) -> BlockSpec:
    return BlockSpec(
        kind="moe",
        moe=L.MoESpec(
            d_model=d_model,
            d_expert=d_expert,
            num_experts=num_experts,
            top_k=top_k,
            num_shared=num_shared,
            d_shared=d_shared,
            capacity_factor=capacity_factor,
        ),
    )


def mamba2_block(d_model: int, d_state: int = 64, chunk: int = 128) -> BlockSpec:
    return BlockSpec(kind="mamba2", mamba=S.Mamba2Spec(d_model=d_model, d_state=d_state, chunk=chunk))


def rwkv6_blocks(d_model: int, d_ff: int, chunk: int = 64) -> Tuple[BlockSpec, BlockSpec]:
    spec = S.RWKV6Spec(d_model=d_model, chunk=chunk)
    return (
        BlockSpec(kind="rwkv6_time", rwkv=spec),
        BlockSpec(kind="rwkv6_channel", rwkv=spec, rwkv_ffn=d_ff),
    )


def dense_lm(
    name: str,
    n_layers: int,
    d_model: int,
    n_heads: int,
    kv_heads: int,
    d_ff: int,
    vocab: int,
    head_dim: Optional[int] = None,
    activation: str = "silu",
    gated: bool = True,
    rope_theta: float = 10000.0,
    tie_embeddings: bool = False,
    qk_norm: bool = False,
    bias: bool = False,
    mrope: bool = False,
    mrope_sections: Tuple[int, int, int] = (16, 24, 24),
) -> ArchConfig:
    hd = head_dim or d_model // n_heads
    layer = (
        attn_block(
            d_model, n_heads, kv_heads, hd,
            rope="mrope" if mrope else "std",
            rope_theta=rope_theta, qk_norm=qk_norm, bias=bias,
            mrope_sections=mrope_sections,
        ),
        mlp_block(d_model, d_ff, activation, gated),
    )
    return ArchConfig(
        name=name,
        vocab=vocab,
        d_model=d_model,
        groups=(GroupSpec(blocks=layer, repeat=n_layers),),
        tie_embeddings=tie_embeddings,
        mrope=mrope,
    )
