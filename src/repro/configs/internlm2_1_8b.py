"""internlm2-1.8b [arXiv:2403.17297]: 24L, d_model 2048, 16 heads / 8 kv
(GQA), head_dim 128, d_ff 8192 (SwiGLU), vocab 92544, rope theta 1e6."""
from repro.configs.base import dense_lm
from repro.models.transformer import ArchConfig


def config() -> ArchConfig:
    return dense_lm(
        "internlm2-1.8b",
        n_layers=24, d_model=2048, n_heads=16, kv_heads=8, d_ff=8192,
        vocab=92544, head_dim=128, activation="silu",
        rope_theta=1000000.0, tie_embeddings=False,
    )


def reduced() -> ArchConfig:
    return dense_lm(
        "internlm2-reduced",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
    )
