"""repro.analysis — repo-native static analysis.

Three rule packs over the repo's own invariants: Pallas kernel contracts
(PL01–PL05), JAX tracer hygiene (JX01–JX05), and IPLS protocol invariants
(PR01–PR02). Run as ``python -m repro.analysis [paths]``; see
docs/ANALYSIS.md for the rule catalogue and suppression syntax.
"""
from repro.analysis.core import (
    Finding,
    Options,
    Rule,
    all_rules,
    analyze_file,
    analyze_paths,
    analyze_source,
    main,
    register,
)

__all__ = [
    "Finding",
    "Options",
    "Rule",
    "all_rules",
    "analyze_file",
    "analyze_paths",
    "analyze_source",
    "main",
    "register",
]
