"""Pallas kernel contract rules (pack ``pallas``).

Every ``pl.pallas_call`` in this repo encodes the same implicit contract:
the grid must cover every output block exactly, block shapes should sit on
the dtype's native (sublane, lane) tiling, the per-step VMEM working set
must fit the budget, and an output block revisited across a grid axis (the
``R_TILE`` accumulation pattern of ``ipls_aggregate_batched``) must guard
its writes with ``@pl.when`` — an unguarded write either clobbers the
accumulator or reads uninitialized memory on the first visit. These rules
resolve grids/BlockSpecs statically, folding module constants (``BR``,
``LANES``, ...) through :class:`repro.analysis.core.ConstEnv`; dimensions
that do not fold (runtime shapes like ``rows``) are skipped, never guessed,
so a finding is always a real structural fact about the call site.

Native minimum tiles (sublane x lane) per dtype — see
/opt/skills/guides/pallas_guide.md:

    float32 (8, 128) | bfloat16/float16 (16, 128) | int8/uint8/fp8 (32, 128)
"""
from __future__ import annotations

import ast
import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.core import (
    Finding,
    FileContext,
    Options,
    Rule,
    deref,
    keyword_arg,
    local_assignments,
    register,
    tail_name,
    walk_calls,
)

# minimum (sublane) rows per dtype; lanes are always 128
SUBLANE = {
    "float32": 8,
    "int32": 8,
    "uint32": 8,
    "bfloat16": 16,
    "float16": 16,
    "int8": 32,
    "uint8": 32,
    "float8_e4m3fn": 32,
    "float8_e5m2": 32,
}
DTYPE_BYTES = {
    "float32": 4,
    "int32": 4,
    "uint32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "uint8": 1,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
}
LANES = 128


@dataclasses.dataclass
class SpecInfo:
    """One parsed BlockSpec (or scratch shape)."""

    node: ast.AST
    shape_vals: Optional[List[Optional[float]]] = None  # None = no block shape
    index_params: Optional[List[str]] = None  # None = no index_map
    index_body: Optional[List[ast.AST]] = None  # elements of the returned tuple
    dtype: Optional[str] = None


@dataclasses.dataclass
class PallasCallInfo:
    node: ast.Call
    kernel_name: Optional[str]
    grid_vals: Optional[List[Optional[float]]]  # None = no/unresolvable grid
    in_specs: List[SpecInfo]
    out_specs: List[SpecInfo]
    out_shapes: List[Tuple[Optional[List[Optional[float]]], Optional[str]]]
    scratch: List[SpecInfo]


def _dtype_tail(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    name = tail_name(node)
    return name if name in SUBLANE else None


def _parse_blockspec(call: ast.AST, ctx: FileContext, env) -> Optional[SpecInfo]:
    call = deref(call, env)
    if not isinstance(call, ast.Call) or tail_name(call.func) != "BlockSpec":
        return None
    info = SpecInfo(node=call)
    shape_node = call.args[0] if call.args else keyword_arg(call, "block_shape")
    index_node = call.args[1] if len(call.args) > 1 else keyword_arg(call, "index_map")
    shape_node = deref(shape_node, env)
    if isinstance(shape_node, (ast.Tuple, ast.List)):
        info.shape_vals = [ctx.consts.fold(el) for el in shape_node.elts]
    index_node = deref(index_node, env)
    if isinstance(index_node, ast.Lambda):
        info.index_params = [a.arg for a in index_node.args.args]
        body = index_node.body
        info.index_body = list(body.elts) if isinstance(body, ast.Tuple) else [body]
    return info


def _parse_out_shape(node: ast.AST, ctx: FileContext, env):
    node = deref(node, env)
    if isinstance(node, ast.Call) and tail_name(node.func) == "ShapeDtypeStruct":
        shape_node = deref(node.args[0] if node.args else keyword_arg(node, "shape"), env)
        dtype_node = node.args[1] if len(node.args) > 1 else keyword_arg(node, "dtype")
        vals = (
            [ctx.consts.fold(el) for el in shape_node.elts]
            if isinstance(shape_node, (ast.Tuple, ast.List))
            else None
        )
        return vals, _dtype_tail(dtype_node)
    return None, None


def _parse_scratch(node: ast.AST, ctx: FileContext, env) -> Optional[SpecInfo]:
    node = deref(node, env)
    # pltpu.VMEM((shape), dtype); SMEM/semaphores are tiny — ignored
    if isinstance(node, ast.Call) and tail_name(node.func) == "VMEM" and node.args:
        shape_node = deref(node.args[0], env)
        info = SpecInfo(node=node)
        if isinstance(shape_node, (ast.Tuple, ast.List)):
            info.shape_vals = [ctx.consts.fold(el) for el in shape_node.elts]
        info.dtype = _dtype_tail(node.args[1] if len(node.args) > 1 else None)
        return info
    return None


def _kernel_name(node: ast.AST) -> Optional[str]:
    """First positional arg of pallas_call: a Name, or functools.partial(Name, ...)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call) and tail_name(node.func) == "partial" and node.args:
        inner = node.args[0]
        if isinstance(inner, ast.Name):
            return inner.id
    return None


def parse_pallas_calls(ctx: FileContext) -> List[PallasCallInfo]:
    out: List[PallasCallInfo] = []
    for call in walk_calls(ctx.tree):
        if tail_name(call.func) != "pallas_call":
            continue
        fn = ctx.enclosing_function(call)
        env = local_assignments(fn) if fn is not None else {}

        grid_node = deref(keyword_arg(call, "grid"), env)
        if isinstance(grid_node, (ast.Tuple, ast.List)):
            grid_vals = [ctx.consts.fold(el) for el in grid_node.elts]
        elif grid_node is not None:
            v = ctx.consts.fold(grid_node)
            grid_vals = [v] if v is not None else None
        else:
            grid_vals = None

        def spec_list(kw: str) -> List[SpecInfo]:
            node = deref(keyword_arg(call, kw), env)
            if node is None:
                return []
            elts = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
            specs = []
            for el in elts:
                s = _parse_blockspec(el, ctx, env)
                if s is not None:
                    specs.append(s)
            return specs

        in_specs = spec_list("in_specs")
        out_specs = spec_list("out_specs")
        shapes_node = deref(keyword_arg(call, "out_shape"), env)
        out_shapes = []
        if shapes_node is not None:
            elts = (
                shapes_node.elts
                if isinstance(shapes_node, (ast.Tuple, ast.List))
                else [shapes_node]
            )
            out_shapes = [_parse_out_shape(el, ctx, env) for el in elts]
        for spec, (_, dt) in zip(out_specs, out_shapes):
            spec.dtype = dt

        scratch_node = deref(keyword_arg(call, "scratch_shapes"), env)
        scratch = []
        if isinstance(scratch_node, (ast.Tuple, ast.List)):
            for el in scratch_node.elts:
                s = _parse_scratch(el, ctx, env)
                if s is not None:
                    scratch.append(s)

        out.append(
            PallasCallInfo(
                node=call,
                kernel_name=_kernel_name(call.args[0]) if call.args else None,
                grid_vals=grid_vals,
                in_specs=in_specs,
                out_specs=out_specs,
                out_shapes=out_shapes,
                scratch=scratch,
            )
        )
    return out


@register
class IndexMapContract(Rule):
    """PL01: index_map arity must equal the grid rank and its returned tuple
    must have one component per block-shape dimension. An arity/rank drift —
    the classic symptom of adding a grid axis without updating every spec —
    compiles to wrong indexing or crashes at trace time deep in Mosaic."""

    id = "PL01"
    pack = "pallas"
    title = "BlockSpec index_map arity/rank must match grid and block shape"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        for info in parse_pallas_calls(ctx):
            n_grid = len(info.grid_vals) if info.grid_vals is not None else None
            for role, specs in (("in", info.in_specs), ("out", info.out_specs)):
                for i, spec in enumerate(specs):
                    if spec.index_params is None:
                        continue
                    if n_grid is not None and len(spec.index_params) != n_grid:
                        yield Finding(
                            self.id,
                            ctx.path,
                            spec.node.lineno,
                            f"{role}_specs[{i}] index_map takes "
                            f"{len(spec.index_params)} args but the grid has "
                            f"{n_grid} axes",
                        )
                    if spec.shape_vals is not None and spec.index_body is not None:
                        if len(spec.index_body) != len(spec.shape_vals):
                            yield Finding(
                                self.id,
                                ctx.path,
                                spec.node.lineno,
                                f"{role}_specs[{i}] index_map returns "
                                f"{len(spec.index_body)} block indices for a "
                                f"rank-{len(spec.shape_vals)} block shape",
                            )


@register
class OutputCoverage(Rule):
    """PL02: every output block must be written by some grid step. Checks the
    resolvable part: a block-index component that is a bare grid parameter
    must sweep exactly ceil(dim / block) blocks; a constant component pins
    that dimension to one block, which is only valid when one block spans the
    whole dimension. Components the folder cannot resolve are skipped."""

    id = "PL02"
    pack = "pallas"
    title = "grid must cover every output block exactly"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        for info in parse_pallas_calls(ctx):
            for i, spec in enumerate(info.out_specs):
                if spec.index_params is None or spec.index_body is None:
                    continue
                shape = spec.shape_vals or []
                out_dims = (
                    info.out_shapes[i][0] if i < len(info.out_shapes) else None
                )
                for d, comp in enumerate(spec.index_body):
                    block_d = shape[d] if d < len(shape) else None
                    out_d = out_dims[d] if out_dims and d < len(out_dims) else None
                    nblocks = (
                        math.ceil(out_d / block_d)
                        if (out_d and block_d)
                        else None
                    )
                    if isinstance(comp, ast.Name) and comp.id in spec.index_params:
                        axis = spec.index_params.index(comp.id)
                        grid_ax = (
                            info.grid_vals[axis]
                            if info.grid_vals is not None
                            and axis < len(info.grid_vals)
                            else None
                        )
                        if grid_ax is not None and nblocks is not None and grid_ax != nblocks:
                            word = "misses" if grid_ax < nblocks else "overruns"
                            yield Finding(
                                self.id,
                                ctx.path,
                                spec.node.lineno,
                                f"out_specs[{i}] dim {d}: grid axis "
                                f"'{comp.id}' sweeps {int(grid_ax)} blocks but the "
                                f"output needs {nblocks} — {word} output blocks",
                            )
                    elif isinstance(comp, ast.Constant) and isinstance(comp.value, int):
                        if comp.value != 0:
                            yield Finding(
                                self.id,
                                ctx.path,
                                spec.node.lineno,
                                f"out_specs[{i}] dim {d} is pinned to block "
                                f"{comp.value}; blocks 0..{comp.value - 1} are "
                                "never written",
                            )
                        elif nblocks is not None and nblocks != 1:
                            yield Finding(
                                self.id,
                                ctx.path,
                                spec.node.lineno,
                                f"out_specs[{i}] dim {d} is pinned to block 0 "
                                f"but the output spans {nblocks} blocks",
                            )


@register
class TileAlignment(Rule):
    """PL03: the last two block dimensions should be multiples of the dtype's
    native (sublane, lane) tile — (8,128) f32, (16,128) bf16, (32,128) int8.
    Misaligned blocks force Mosaic to pad every VMEM tile (silent bandwidth
    loss) and some layouts are rejected outright on real TPUs. Inputs default
    to the f32 tile when their dtype is unknowable; outputs use the
    ``out_shape`` dtype. Rank-0/1 blocks and unresolvable dims are skipped;
    VMEM *scratch* is exempt (private, compiler-padded — PL04 budgets it)."""

    id = "PL03"
    pack = "pallas"
    title = "block shapes should align to the dtype's native (sublane, lane) tile"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        for info in parse_pallas_calls(ctx):
            for role, specs in (("in", info.in_specs), ("out", info.out_specs)):
                for i, spec in enumerate(specs):
                    if not spec.shape_vals or len(spec.shape_vals) < 2:
                        continue
                    sub, lane = spec.shape_vals[-2], spec.shape_vals[-1]
                    if sub is None or lane is None:
                        continue
                    dtype = spec.dtype or "float32"
                    need_sub = SUBLANE[dtype]
                    bad_lane = lane % LANES != 0
                    bad_sub = sub % need_sub != 0
                    if bad_lane or bad_sub:
                        yield Finding(
                            self.id,
                            ctx.path,
                            spec.node.lineno,
                            f"{role}_specs[{i}] block tail "
                            f"({int(sub)}, {int(lane)}) is not a multiple of the "
                            f"native {dtype} tile ({need_sub}, {LANES})",
                        )


@register
class VmemBudget(Rule):
    """PL04: estimated per-grid-step VMEM footprint must fit the budget
    (default 16 MiB, ``--vmem-budget-mb``). Model: 2x every in/out block
    (the pipeline double-buffers HBM<->VMEM copies) plus scratch, bytes from
    the resolved dtype (inputs default f32). Specs with unresolvable dims are
    left out, so the estimate is a lower bound — an over-budget finding is
    real, a pass is best-effort."""

    id = "PL04"
    pack = "pallas"
    title = "estimated VMEM working set exceeds the budget"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        for info in parse_pallas_calls(ctx):
            total = 0
            for spec in info.in_specs + info.out_specs:
                if not spec.shape_vals or any(v is None for v in spec.shape_vals):
                    continue
                nbytes = DTYPE_BYTES[spec.dtype or "float32"]
                total += 2 * int(math.prod(spec.shape_vals)) * nbytes
            for spec in info.scratch:
                if not spec.shape_vals or any(v is None for v in spec.shape_vals):
                    continue
                nbytes = DTYPE_BYTES[spec.dtype or "float32"]
                total += int(math.prod(spec.shape_vals)) * nbytes
            if total > options.vmem_budget_bytes:
                yield Finding(
                    self.id,
                    ctx.path,
                    info.node.lineno,
                    f"estimated VMEM working set {total / 2**20:.1f} MiB exceeds "
                    f"the {options.vmem_budget_bytes / 2**20:.0f} MiB budget "
                    "(2x in/out blocks + scratch)",
                )


def _guarded_nodes(kernel: ast.FunctionDef) -> set:
    """All AST nodes inside nested functions decorated with ``@pl.when``."""
    guarded: set = set()
    for node in ast.walk(kernel):
        if isinstance(node, ast.FunctionDef) and node is not kernel:
            if any(
                isinstance(dec, ast.Call) and tail_name(dec.func) == "when"
                for dec in node.decorator_list
            ):
                for sub in ast.walk(node):
                    guarded.add(id(sub))
    return guarded


@register
class RevisitedAccumulation(Rule):
    """PL05: an output whose index_map ignores a grid axis is revisited — the
    same block is live across every step of that axis, so every write to its
    ref must sit under ``@pl.when`` (the init/accumulate/emit pattern of
    ``ipls_aggregate_batched``'s R_TILE walk). An unguarded write either
    clobbers partial accumulation or, on the first visit, reads a block that
    was never initialized."""

    id = "PL05"
    pack = "pallas"
    title = "revisited output block written without @pl.when guard"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        kernels: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree) if isinstance(n, ast.FunctionDef)
        }
        for info in parse_pallas_calls(ctx):
            kernel = kernels.get(info.kernel_name or "")
            if kernel is None:
                continue
            pos_params = [a.arg for a in kernel.args.args]  # kw-only are static
            n_in = len(info.in_specs)
            guarded = _guarded_nodes(kernel)
            for i, spec in enumerate(info.out_specs):
                if spec.index_params is None or spec.index_body is None:
                    continue
                used = {
                    n.id
                    for comp in spec.index_body
                    for n in ast.walk(comp)
                    if isinstance(n, ast.Name)
                }
                ignored = [p for p in spec.index_params if p not in used]
                if not ignored:
                    continue  # every grid axis moves the block: no revisit
                if n_in + i >= len(pos_params):
                    continue
                ref = pos_params[n_in + i]
                for node in ast.walk(kernel):
                    if not isinstance(node, (ast.Assign, ast.AugAssign)):
                        continue
                    targets = (
                        node.targets if isinstance(node, ast.Assign) else [node.target]
                    )
                    for tgt in targets:
                        if (
                            isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == ref
                            and id(node) not in guarded
                        ):
                            yield Finding(
                                self.id,
                                ctx.path,
                                node.lineno,
                                f"kernel '{kernel.name}' writes revisited output "
                                f"ref '{ref}' (block constant across grid "
                                f"axis '{ignored[0]}') outside any @pl.when "
                                "guard",
                            )
