"""CLI entry point: ``python -m repro.analysis [paths]``."""
from repro.analysis.core import main

if __name__ == "__main__":
    raise SystemExit(main())
