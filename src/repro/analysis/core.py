"""Repo-native static-analysis framework.

The invariants this repo's correctness rests on — Pallas BlockSpec/grid
contracts, host/device boundary discipline inside traced code, the keyed
fate-stream and traffic-counter symmetry between the scalar and vectorized
engines — are not checkable by generic linters. Each was violated at least
once in PRs 1-6 and only caught by equivalence tests after the fact. This
module is the shared machinery for rule packs that check them at review
time instead:

  * ``Rule`` / ``@register`` — a registry of AST-visitor rules, each with a
    stable id (``PL01`` ... ``PR02``), grouped into packs (``pallas``,
    ``jax``, ``protocol``);
  * ``FileContext`` — one parsed file: source, AST, per-line
    ``# repro: noqa[RULE]`` suppressions, and a best-effort constant folder
    seeded with module-level integer/float constants (``BR = 256`` etc.) so
    rules can resolve tile shapes and grids built from named constants;
  * ``analyze_paths`` / ``main`` — directory traversal (fixture snippets
    under ``analysis_fixtures`` are excluded from tree walks but analyzable
    by explicit path), human and JSON output, exit code 1 iff findings.

Suppression syntax, on the offending line (or on comment-only lines
immediately above it, for multi-line constructs)::

    x = something_flagged()  # repro: noqa[JX01] reason why this is safe

Multiple ids separate with commas; the reason text is free-form but
required by convention (docs/ANALYSIS.md).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Za-z0-9_,\s]+)\]")

# directories never entered during tree walks (fixture snippets deliberately
# violate the rules; explicit file arguments bypass this)
DEFAULT_EXCLUDED_DIRS = {"analysis_fixtures", "__pycache__", ".git"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ConstEnv:
    """Best-effort constant folding over a module's top-level bindings.

    Resolves integer/float expressions built from literals, previously
    resolved module constants, and ``+ - * // % **`` / unary minus. Anything
    else (function parameters, shapes, calls) folds to None — rules must
    treat None as "unknown, skip the numeric part of the check" so the
    analyzer never guesses.
    """

    def __init__(self, tree: ast.Module):
        self.values: Dict[str, float] = {}
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    val = self.fold(node.value)
                    if val is not None:
                        self.values[tgt.id] = val

    def fold(self, node: ast.AST, local: Optional[Dict[str, float]] = None):
        if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
            if isinstance(node.value, bool):
                return None
            return node.value
        if isinstance(node, ast.Name):
            if local and node.id in local:
                return local[node.id]
            return self.values.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.fold(node.operand, local)
            return None if v is None else -v
        if isinstance(node, ast.BinOp):
            lhs = self.fold(node.left, local)
            rhs = self.fold(node.right, local)
            if lhs is None or rhs is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return lhs + rhs
                if isinstance(node.op, ast.Sub):
                    return lhs - rhs
                if isinstance(node.op, ast.Mult):
                    return lhs * rhs
                if isinstance(node.op, ast.FloorDiv):
                    return lhs // rhs
                if isinstance(node.op, ast.Div):
                    return lhs / rhs
                if isinstance(node.op, ast.Mod):
                    return lhs % rhs
                if isinstance(node.op, ast.Pow):
                    return lhs**rhs
            except (ZeroDivisionError, OverflowError, ValueError):
                return None
        return None

    def fold_tuple(self, node: ast.AST, local=None) -> Optional[List[Optional[float]]]:
        """Fold a tuple/list expression element-wise; None elements mark
        unresolvable dims. Returns None when the node is not a tuple/list."""
        if isinstance(node, (ast.Tuple, ast.List)):
            return [self.fold(el, local) for el in node.elts]
        return None


class FileContext:
    """One source file as seen by every rule."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.consts = ConstEnv(tree)
        # line -> set of suppressed rule ids (upper-cased)
        self.noqa: Dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = NOQA_RE.search(line)
            if m:
                ids = {s.strip().upper() for s in m.group(1).split(",") if s.strip()}
                self.noqa[i] = ids
        # parent links let rules find enclosing functions
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parent(cur)
        return None

    def _noqa_matches(self, line: int, rule: str) -> bool:
        ids = self.noqa.get(line)
        return bool(ids) and (rule in ids or "ALL" in ids)

    def suppressed(self, finding: Finding) -> bool:
        rule = finding.rule.upper()
        if self._noqa_matches(finding.line, rule):
            return True
        # a noqa may also sit on comment-only lines immediately above the
        # finding — the only readable placement inside multi-line constructs
        # like a BlockSpec list
        i = finding.line - 1
        while 1 <= i <= len(self.lines) and self.lines[i - 1].lstrip().startswith("#"):
            if self._noqa_matches(i, rule):
                return True
            i -= 1
        return False


class Rule:
    """Base class: subclasses set ``id``/``pack``/``title`` and implement
    ``check``; register with :func:`register`."""

    id: str = ""
    pack: str = ""
    title: str = ""

    def check(self, ctx: FileContext, options: "Options") -> Iterator[Finding]:
        raise NotImplementedError


@dataclasses.dataclass
class Options:
    """Knobs shared by the CLI and the test harness."""

    vmem_budget_bytes: int = 16 * 1024 * 1024
    select: Optional[set] = None  # rule ids; None = all


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate and add to the global registry."""
    rule = cls()
    if not rule.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if rule.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id}")
    _REGISTRY[rule.id] = rule
    return cls


def all_rules() -> Dict[str, Rule]:
    _load_packs()
    return dict(_REGISTRY)


_PACKS_LOADED = False


def _load_packs() -> None:
    # import for the @register side effects; deferred so core can be imported
    # by the rule modules themselves without a cycle
    global _PACKS_LOADED
    if _PACKS_LOADED:
        return
    _PACKS_LOADED = True
    from repro.analysis import rules_jax, rules_pallas, rules_protocol  # noqa: F401


def analyze_source(
    path: str, source: str, options: Optional[Options] = None
) -> List[Finding]:
    """Analyze one file's source text; returns findings after noqa filtering.
    Syntax errors surface as a single ``SYNTAX`` finding rather than a crash
    so a broken file fails the gate visibly."""
    options = options or Options()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("SYNTAX", path, e.lineno or 1, f"syntax error: {e.msg}")]
    ctx = FileContext(path, source, tree)
    findings: List[Finding] = []
    for rule in all_rules().values():
        if options.select and rule.id not in options.select:
            continue
        for f in rule.check(ctx, options):
            if not ctx.suppressed(f):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def analyze_file(path, options: Optional[Options] = None) -> List[Finding]:
    p = Path(path)
    return analyze_source(str(p), p.read_text(), options)


def iter_python_files(paths: Sequence[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_file():
            yield p  # explicit files bypass the excludes (fixture tests rely on this)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not DEFAULT_EXCLUDED_DIRS.intersection(f.parts):
                    yield f
        else:
            raise FileNotFoundError(raw)


def analyze_paths(paths: Sequence[str], options: Optional[Options] = None) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(analyze_file(f, options))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-native static analysis: Pallas kernel contracts, "
        "JAX tracer hygiene, protocol invariants (docs/ANALYSIS.md).",
    )
    parser.add_argument("paths", nargs="*", default=["src"], help="files or directories")
    parser.add_argument("--json", action="store_true", help="machine-readable output")
    parser.add_argument(
        "--select", default=None, help="comma-separated rule ids to run (default: all)"
    )
    parser.add_argument(
        "--vmem-budget-mb",
        type=float,
        default=16.0,
        help="VMEM budget for PL04 in MiB (default 16)",
    )
    parser.add_argument("--list-rules", action="store_true", help="print the rule catalogue")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in sorted(all_rules().values(), key=lambda r: r.id):
            print(f"{rule.id}  [{rule.pack}]  {rule.title}")
        return 0

    options = Options(
        vmem_budget_bytes=int(args.vmem_budget_mb * 1024 * 1024),
        select={s.strip().upper() for s in args.select.split(",")} if args.select else None,
    )
    findings = analyze_paths(args.paths, options)
    if args.json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        n_files = sum(1 for _ in iter_python_files(args.paths))
        print(
            f"repro.analysis: {len(findings)} finding(s) in {n_files} file(s)",
            file=sys.stderr,
        )
    return 1 if findings else 0


# ---------------------------------------------------------------------------
# shared AST helpers used by the rule packs
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str:
    """'jax.lax.scan' for an Attribute/Name chain, '' when not a plain chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def tail_name(node: ast.AST) -> str:
    """Last attribute segment: 'scan' for jax.lax.scan, the id for a Name."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def walk_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def local_assignments(fn: ast.AST) -> Dict[str, ast.AST]:
    """name -> last assigned expression, single-target assigns only. Used to
    deref e.g. ``grid = (rows // BR,)`` at a ``grid=grid`` call site."""
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                out[tgt.id] = node.value
    return out


def deref(node: Optional[ast.AST], env: Dict[str, ast.AST], depth: int = 4) -> Optional[ast.AST]:
    """Follow Name -> assigned-expression chains a bounded number of steps."""
    while depth > 0 and isinstance(node, ast.Name) and node.id in env:
        nxt = env[node.id]
        if nxt is node:
            break
        node = nxt
        depth -= 1
    return node
