"""JAX tracer-hygiene rules (pack ``jax``).

Inside code that JAX traces (functions reaching ``jit`` / ``vmap`` /
``lax.scan`` / ``pl.pallas_call`` call sites), Python-level control flow and
host coercions on tracer values either crash with a
``ConcretizationTypeError`` or — worse — silently bake one traced value into
the compiled program (the bug class that made ``run_window``'s control plane
fragile until it was pulled host-side). These rules build a module-local
traced-reachability set and a conservative taint analysis:

  * a function is *traced* when its name (or a lambda) is passed to a
    tracing API or it is called, transitively, from a traced function in the
    same module;
  * a value is *tainted* (tracer-typed) when it derives from a traced
    function's positional parameters or from ``pl.program_id``-style calls.
    Keyword-only parameters and names in ``static_argnames`` are static by
    construction (the repo binds them via ``functools.partial`` with
    literals), and ``.shape`` / ``.dtype`` / ``.ndim`` / ``len()`` accesses
    are static metadata — none of these taint.

The taint set is deliberately an under-approximation: a finding is a real
host/device boundary violation, while clean output is best-effort (closure
captures of device arrays are not tracked). Host-side control planes (e.g.
``_control_round``) are never flagged because nothing traces them.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import (
    Finding,
    FileContext,
    Options,
    Rule,
    call_name,
    keyword_arg,
    register,
    tail_name,
)

# tracing APIs whose FIRST positional argument is traced
FN_FIRST_ARG = {
    "jit",
    "vmap",
    "pmap",
    "grad",
    "value_and_grad",
    "remat",
    "checkpoint",
    "pallas_call",
    "scan",  # jax.lax.scan(body, ...)
    "while_loop",  # cond_fun
    "custom_vjp",
}
# attribute reads that yield static metadata, not tracers
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding", "weak_type"}
# calls whose results are tracers even without tainted arguments
TRACER_SOURCES = {"program_id", "num_programs"}
# annotation tails that mean "this positional param is (or may be) a traced
# array"; anything else annotated (str, int, BlockSpec, ...) is declared
# static by the author — the repo's convention for config params threaded
# through traced code
ARRAYISH_ANNOTATIONS = {"Array", "ndarray", "ArrayLike", "DeviceArray", "Any", "object"}
IMPURE_PREFIXES = ("np.random.", "numpy.random.", "random.")
IMPURE_CALLS = {
    "time.time",
    "time.perf_counter",
    "time.monotonic",
    "time.time_ns",
    "datetime.now",
    "datetime.datetime.now",
}
MUTATING_METHODS = {"append", "extend", "update", "pop", "setdefault", "insert", "clear"}


@dataclasses.dataclass
class TracedFn:
    """One traced callable: a FunctionDef or a Lambda."""

    name: str
    node: ast.AST  # FunctionDef | Lambda
    static_params: Set[str]

    @property
    def body(self) -> List[ast.AST]:
        if isinstance(self.node, ast.Lambda):
            return [self.node.body]
        return self.node.body

    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in (a.posonlyargs + a.args)]

    def kwonly_params(self) -> Set[str]:
        return {p.arg for p in self.node.args.kwonlyargs}


def _literal_str_elts(node: Optional[ast.AST]) -> Set[str]:
    out: Set[str] = set()
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
    elif isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.add(node.value)
    return out


def _fn_refs(node: ast.AST) -> Tuple[Optional[str], Optional[ast.Lambda], Set[str]]:
    """Resolve a callable argument: (name, lambda, partial-bound kwargs)."""
    if isinstance(node, ast.Name):
        return node.id, None, set()
    if isinstance(node, ast.Lambda):
        return None, node, set()
    if isinstance(node, ast.Call) and tail_name(node.func) == "partial" and node.args:
        inner = node.args[0]
        bound = {kw.arg for kw in node.keywords if kw.arg}
        if isinstance(inner, ast.Name):
            return inner.id, None, bound
    return None, None, set()


class TracedIndex:
    """Module-local traced-reachability: roots from tracing call sites and
    decorators, closed transitively over same-module calls by name."""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        # every def (incl. nested) and every name-bound lambda, by name
        self.defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                self.defs.setdefault(node.name, []).append(node)
            elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.defs.setdefault(tgt.id, []).append(node.value)

        self.static_of: Dict[str, Set[str]] = {}
        roots: Set[str] = set()
        self.lambda_roots: List[ast.Lambda] = []
        self.scan_bodies: List[Tuple[ast.Call, Optional[str], Optional[ast.Lambda]]] = []
        self.cond_sites: List[ast.Call] = []

        def add_root(node: Optional[ast.AST]):
            if node is None:
                return
            name, lam, bound = _fn_refs(node)
            if name:
                roots.add(name)
                if bound:
                    self.static_of.setdefault(name, set()).update(bound)
            elif lam is not None:
                self.lambda_roots.append(lam)

        for call in (n for n in ast.walk(ctx.tree) if isinstance(n, ast.Call)):
            tail = tail_name(call.func)
            if tail in FN_FIRST_ARG and call.args:
                add_root(call.args[0])
                if tail in ("jit", "pmap"):
                    name, _, _ = _fn_refs(call.args[0])
                    if name:
                        self.static_of.setdefault(name, set()).update(
                            _literal_str_elts(keyword_arg(call, "static_argnames"))
                        )
            elif tail == "cond" and len(call.args) >= 3:
                self.cond_sites.append(call)
                add_root(call.args[1])
                add_root(call.args[2])
            elif tail == "while_loop" and len(call.args) >= 2:
                add_root(call.args[0])
                add_root(call.args[1])
            elif tail == "fori_loop" and len(call.args) >= 3:
                add_root(call.args[2])
            elif tail == "switch" and len(call.args) >= 2:
                branches = call.args[1]
                if isinstance(branches, (ast.Tuple, ast.List)):
                    for el in branches.elts:
                        add_root(el)
            if tail == "scan" and call.args:
                name, lam, _ = _fn_refs(call.args[0])
                self.scan_bodies.append((call, name, lam))

        # decorator roots: @jax.jit, @functools.partial(jax.jit, ...)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            for dec in node.decorator_list:
                d_tail = tail_name(dec if not isinstance(dec, ast.Call) else dec.func)
                if d_tail in ("jit", "vmap", "pmap", "grad"):
                    roots.add(node.name)
                    if isinstance(dec, ast.Call):
                        self.static_of.setdefault(node.name, set()).update(
                            _literal_str_elts(keyword_arg(dec, "static_argnames"))
                        )
                elif d_tail == "partial" and isinstance(dec, ast.Call) and dec.args:
                    inner = dec.args[0]
                    if tail_name(inner) in ("jit", "vmap", "pmap"):
                        roots.add(node.name)
                        self.static_of.setdefault(node.name, set()).update(
                            _literal_str_elts(keyword_arg(dec, "static_argnames"))
                        )

        # transitive closure over same-module calls by bare name
        traced = set()
        frontier = [r for r in roots if r in self.defs]
        while frontier:
            name = frontier.pop()
            if name in traced:
                continue
            traced.add(name)
            for fn in self.defs[name]:
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name):
                        callee = sub.func.id
                        if callee in self.defs and callee not in traced:
                            frontier.append(callee)
        self.traced_names = traced

    def traced_fns(self) -> Iterator[TracedFn]:
        for name in sorted(self.traced_names):
            for node in self.defs[name]:
                static = set(self.static_of.get(name, set()))
                if isinstance(node, ast.FunctionDef):
                    static |= {p.arg for p in node.args.kwonlyargs}
                yield TracedFn(name, node, static)
        for lam in self.lambda_roots:
            yield TracedFn(f"<lambda@{lam.lineno}>", lam, set())


def _annotated_static(param: ast.arg) -> bool:
    """A positional param annotated with a non-array type (str, int, a config
    dataclass) is static by declaration."""
    ann = param.annotation
    if isinstance(ann, (ast.Name, ast.Attribute)):
        return tail_name(ann) not in ARRAYISH_ANNOTATIONS
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        tail = ann.value.split("[")[0].split(".")[-1].strip()
        return tail not in ARRAYISH_ANNOTATIONS
    return False  # unannotated / container annotations: may hold arrays


def _depends(node: ast.AST, tainted: Set[str]) -> bool:
    """Does ``node`` read a tainted name outside static-metadata accesses?"""
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id == "len":
            return False
        # method calls: the receiver may still be tainted (x.sum()); only the
        # .shape-style chains above are static
    if isinstance(node, ast.Compare):
        # `x is None` is a host-side identity check, never a traced value
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        # `key in pytree` with a static key inspects dict *structure*
        if all(
            isinstance(op, (ast.In, ast.NotIn)) for op in node.ops
        ) and not _depends(node.left, tainted):
            return False
        # equality against a string constant is config dispatch, not math
        if any(
            isinstance(c, ast.Constant) and isinstance(c.value, str)
            for c in [node.left, *node.comparators]
        ):
            return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    return any(_depends(child, tainted) for child in ast.iter_child_nodes(node))


def _taint_names(target: ast.AST) -> Iterator[str]:
    """Names bound by an assignment target. For ``d[k] = v`` only the
    container ``d`` becomes tainted — the index ``k`` is read, not bound."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            yield from _taint_names(el)
    elif isinstance(target, ast.Starred):
        yield from _taint_names(target.value)
    elif isinstance(target, (ast.Subscript, ast.Attribute)):
        base = target.value
        while isinstance(base, (ast.Subscript, ast.Attribute)):
            base = base.value
        if isinstance(base, ast.Name):
            yield base.id


def taint_set(fn: TracedFn) -> Set[str]:
    """Params minus statics, plus anything assigned from tainted expressions
    or tracer sources; two ordered passes approximate the fixpoint."""
    a = fn.node.args
    tainted = {
        p.arg for p in (a.posonlyargs + a.args) if not _annotated_static(p)
    } - fn.static_params
    for _ in range(2):
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = node.value
                if value is None:
                    continue
                targets = (
                    node.targets if isinstance(node, ast.Assign) else [node.target]
                )
                is_source = isinstance(value, ast.Call) and tail_name(
                    value.func
                ) in TRACER_SOURCES
                if is_source or _depends(value, tainted):
                    for tgt in targets:
                        tainted.update(_taint_names(tgt))
    return tainted


def _traced_index(ctx: FileContext) -> TracedIndex:
    # cache on the context: four rules share one reachability build
    idx = getattr(ctx, "_traced_index", None)
    if idx is None:
        idx = TracedIndex(ctx)
        ctx._traced_index = idx
    return idx


@register
class HostCoercion(Rule):
    """JX01: ``int()``/``float()``/``bool()`` on a tracer raises a
    ConcretizationTypeError under jit — or, under ``lax.scan``'s tracing of
    the first iteration, silently freezes iteration-0's value into every
    step. Host-side coercions belong in the control plane, before the traced
    boundary."""

    id = "JX01"
    pack = "jax"
    title = "int()/float()/bool() on a traced value"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        idx = _traced_index(ctx)
        for fn in idx.traced_fns():
            tainted = taint_set(fn)
            for node in ast.walk(fn.node):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in ("int", "float", "bool", "complex")
                    and node.args
                    and _depends(node.args[0], tainted)
                ):
                    yield Finding(
                        self.id,
                        ctx.path,
                        node.lineno,
                        f"{node.func.id}() applied to traced value inside "
                        f"'{fn.name}' — hoist to the host control plane or use "
                        "jnp casts",
                    )


@register
class PythonControlFlow(Rule):
    """JX02: Python ``if``/``while``/``assert`` branching on a tracer is
    evaluated ONCE at trace time — the compiled program keeps whichever
    branch the tracer happened to take. Use ``lax.cond`` / ``lax.select`` /
    ``pl.when``. (Branching on ``.shape``/``.dtype`` or static kwargs is
    fine and not flagged.)"""

    id = "JX02"
    pack = "jax"
    title = "Python control flow on a traced value"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        idx = _traced_index(ctx)
        for fn in idx.traced_fns():
            tainted = taint_set(fn)
            for node in ast.walk(fn.node):
                test = None
                kind = None
                if isinstance(node, (ast.If, ast.While)):
                    test, kind = node.test, type(node).__name__.lower()
                elif isinstance(node, ast.IfExp):
                    test, kind = node.test, "conditional expression"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                if test is not None and _depends(test, tainted):
                    yield Finding(
                        self.id,
                        ctx.path,
                        node.lineno,
                        f"Python {kind} on traced value inside '{fn.name}' — "
                        "use lax.cond/lax.select (or pl.when in kernels)",
                    )


@register
class ImpureTracedCall(Rule):
    """JX03: ``numpy.random``/``time``/``datetime`` calls inside traced code
    execute once at trace time and the result is burned into the compiled
    program as a constant — every subsequent call replays it. Randomness
    must come through ``jax.random`` keys (or the keyed fate stream);
    timing belongs outside the traced boundary."""

    id = "JX03"
    pack = "jax"
    title = "trace-time host side effect (numpy.random / time / datetime)"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        idx = _traced_index(ctx)
        for fn in idx.traced_fns():
            for node in ast.walk(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name in IMPURE_CALLS or name.startswith(IMPURE_PREFIXES):
                    yield Finding(
                        self.id,
                        ctx.path,
                        node.lineno,
                        f"'{name}' inside traced '{fn.name}' runs once at "
                        "trace time and is constant thereafter",
                    )


@register
class ScanCarryMutation(Rule):
    """JX04: mutating the carry inside a ``lax.scan`` body (item assignment,
    ``.append``/``.update``/... on carry-derived names) either crashes (JAX
    arrays are immutable) or — for Python dict/list carries — leaks state
    across the traced iteration boundary so every step sees trace-time
    contents. Carries must be rebuilt functionally (``.at[].set``, fresh
    pytrees)."""

    id = "JX04"
    pack = "jax"
    title = "scan carry mutated inside the body"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        idx = _traced_index(ctx)
        for call, name, lam in idx.scan_bodies:
            body_fns: List[ast.AST] = []
            if lam is not None:
                body_fns.append(lam)
            elif name and name in idx.defs:
                body_fns.extend(idx.defs[name])
            for fn in body_fns:
                params = (
                    [a.arg for a in fn.args.args] if fn.args.args else []
                )
                if not params:
                    continue
                carry_names = {params[0]}
                # names unpacked from the carry: `a, b = carry`
                for node in ast.walk(fn):
                    if isinstance(node, ast.Assign) and isinstance(node.value, ast.Name):
                        if node.value.id in carry_names:
                            for tgt in node.targets:
                                carry_names.update(_taint_names(tgt))
                for node in ast.walk(fn):
                    if isinstance(node, (ast.Assign, ast.AugAssign)):
                        targets = (
                            node.targets
                            if isinstance(node, ast.Assign)
                            else [node.target]
                        )
                        for tgt in targets:
                            if (
                                isinstance(tgt, ast.Subscript)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id in carry_names
                            ):
                                yield Finding(
                                    self.id,
                                    ctx.path,
                                    node.lineno,
                                    f"scan body mutates carry "
                                    f"'{tgt.value.id}' by item assignment — "
                                    "rebuild with .at[].set()",
                                )
                    elif isinstance(node, ast.Call):
                        f = node.func
                        if (
                            isinstance(f, ast.Attribute)
                            and f.attr in MUTATING_METHODS
                            and isinstance(f.value, ast.Name)
                            and f.value.id in carry_names
                        ):
                            yield Finding(
                                self.id,
                                ctx.path,
                                node.lineno,
                                f"scan body mutates carry '{f.value.id}' via "
                                f".{f.attr}() — carries must be rebuilt "
                                "functionally",
                            )
                    elif isinstance(node, ast.Delete):
                        for tgt in node.targets:
                            if (
                                isinstance(tgt, ast.Subscript)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id in carry_names
                            ):
                                yield Finding(
                                    self.id,
                                    ctx.path,
                                    node.lineno,
                                    f"scan body deletes from carry "
                                    f"'{tgt.value.id}'",
                                )


def _return_tree(node: ast.AST):
    """Structural pytree skeleton of a return expression: nested tuple
    arities, with None leaves for anything opaque."""
    if isinstance(node, (ast.Tuple, ast.List)):
        return [_return_tree(el) for el in node.elts]
    return None


def _trees_conflict(a, b) -> bool:
    if a is None or b is None:
        return False  # opaque: could be anything — never guess
    if len(a) != len(b):
        return True
    return any(_trees_conflict(x, y) for x, y in zip(a, b))


@register
class CondPytreeMismatch(Rule):
    """JX05: ``lax.cond`` branches must return identical pytree structures;
    a mismatch is a trace-time TypeError whose message points at neither
    branch. Checked structurally for lambda / same-module function branches
    whose returns are literal tuples; opaque returns are skipped."""

    id = "JX05"
    pack = "jax"
    title = "lax.cond branches return mismatched pytree structures"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        idx = _traced_index(ctx)
        for call in idx.cond_sites:
            trees = []
            for branch in call.args[1:3]:
                name, lam, _ = _fn_refs(branch)
                if lam is not None:
                    trees.append(_return_tree(lam.body))
                elif name and name in idx.defs:
                    fn = idx.defs[name][0]
                    rets = [
                        n.value
                        for n in ast.walk(fn)
                        if isinstance(n, ast.Return) and n.value is not None
                    ]
                    trees.append(_return_tree(rets[0]) if rets else None)
                else:
                    trees.append(None)
            if len(trees) == 2 and _trees_conflict(trees[0], trees[1]):
                yield Finding(
                    self.id,
                    ctx.path,
                    call.lineno,
                    "lax.cond branches return different pytree structures "
                    f"({_arity(trees[0])} vs {_arity(trees[1])} elements)",
                )


def _arity(tree) -> str:
    return "opaque" if tree is None else str(len(tree))
