"""IPLS protocol-invariant rules (pack ``protocol``).

The scalar pubsub engine (``p2p/ipfs_sim.py`` + ``fl/rounds.py``) and the
vectorized engine (``fl/vectorized.py``) are kept provably equivalent by two
conventions that nothing type-checks:

  * **Keyed fates** — every message fate is drawn from the counter-based
    stream keyed by the full tuple ``(channel, round, agent, part[, peer])``.
    A draw site that omits part of the key collapses distinct messages onto
    one fate and silently desynchronizes the engines (the PR-1 pubsub
    double-fan-out bug was exactly this class).
  * **Counter symmetry** — every site that bumps a traffic counter
    (``messages_sent`` / ``messages_dropped`` / byte totals) must have a
    declared counterpart in the other engine, recorded in the ``SYMMETRY``
    table below. An undeclared increment is a counter the equivalence tests
    can drift on; a stale declaration is a site someone deleted without
    updating the mirror.
  * **Dtype-derived wire bytes** — byte accounting must come from the
    payload's dtype/size (``core.wire.wire_size``, ``.nbytes``), never an
    element count times a literal width: the quantized (int8) wire makes
    ``n * 4`` wrong for every compressed transfer.

When adding an accounting site, add it here together with its counterpart
(`tests/test_analysis.py` asserts the table stays two-sided).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.core import Finding, FileContext, Options, Rule, register

FATE_DRAW_METHODS = {"draw", "draw_one", "draw_window"}
# (channel, round, agent, part) — peer optional for point-to-point channels
MIN_KEY_ARITY = 4

# traffic counters, as they appear as attribute/subscript targets
COUNTERS = {
    "messages_sent",
    "messages_dropped",
    "bytes_total",
    "_bytes_total",
    "bytes_sent",
    "bytes_recv",
}

# Declared-symmetry table: path suffix -> function -> counters it bumps.
# The scalar block and the vectorized block mirror each other; equivalence
# tests (test_lossy_equivalence) rely on both sides counting the same events.
SYMMETRY: Dict[str, Dict[str, Set[str]]] = {
    # scalar engine: per-message accounting in the pubsub transport
    "p2p/ipfs_sim.py": {
        "publish": {"messages_sent", "messages_dropped", "bytes_sent"},
        "send": {"messages_sent", "messages_dropped", "bytes_sent"},
        "tick": {"messages_dropped", "bytes_recv"},
    },
    # vectorized engine: per-round bulk accounting from the device counters,
    # plus the churn re-snapshot boundary crossings that move pubsub state
    # between the oracle and the dense planes (docs/ENGINE.md "Churn
    # re-snapshot") — they mirror the scalar tick's delivery accounting
    "fl/vectorized.py": {
        "_run_round_lossy": {"messages_sent", "messages_dropped", "_bytes_total"},
        "_run_window_lossy": {"messages_sent", "messages_dropped", "_bytes_total"},
        "_perfect_traffic": {"messages_sent", "_bytes_total"},
        "_init_lossy": {"bytes_recv"},
        "_harvest_pubsub": {"bytes_recv"},
        "_device_to_scalar": {"bytes_sent", "bytes_recv"},
    },
}

# engine side of each declared file, used by the table self-check
ENGINE_SIDE = {"p2p/ipfs_sim.py": "scalar", "fl/vectorized.py": "vectorized"}

# -- PR04: telemetry metric-schema symmetry ---------------------------------
# Hardcoded mirrors of repro.telemetry.schema.FINISH_KEYS / CHANNELS.
# tests/test_analysis.py cross-checks these against the live schema module,
# so drift between the rule and the schema is itself a test failure.
METRIC_FINISH_KEYS = (
    "round",
    "active",
    "contrib",
    "eps",
    "delta_normsq",
    "value_normsq",
    "accs",
    "bytes_total",
    "msgs_total",
    "drops_total",
)
METRIC_CHANNELS = (
    "fetch",
    "fetch_reply",
    "update",
    "update_reply",
    "replica",
    "member",
)

# Declared emitters: path suffix -> the function holding that engine's ONE
# finish_round emission site. A file matching the suffix that defines the
# function without a finish_round call inside it lost its emission site; a
# partial file (fixture) omitting the function is skipped, like SYMMETRY.
EMITTER_FUNCS: Dict[str, str] = {
    "fl/rounds.py": "_tel_finish",
    "fl/vectorized.py": "_emit_row",
}

_FAMILY = {
    "messages_sent": "messages_sent",
    "messages_dropped": "messages_dropped",
    "bytes_total": "bytes",
    "_bytes_total": "bytes",
    "bytes_sent": "bytes",
    "bytes_recv": "bytes",
}


def symmetry_is_balanced() -> Dict[str, Set[str]]:
    """Counter families present per engine side; a balanced table has the
    same families on both sides. Exposed for the meta-test."""
    sides: Dict[str, Set[str]] = {"scalar": set(), "vectorized": set()}
    for suffix, funcs in SYMMETRY.items():
        side = ENGINE_SIDE[suffix]
        for counters in funcs.values():
            sides[side].update(_FAMILY[c] for c in counters)
    return sides


def _counter_target(node: ast.AST) -> Optional[str]:
    """Base counter name of an AugAssign target, unwrapping subscripts."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in COUNTERS:
        return node.attr
    if isinstance(node, ast.Name) and node.id in COUNTERS:
        return node.id
    return None


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def _declared_for(path: str) -> Optional[Dict[str, Set[str]]]:
    p = _norm(path)
    for suffix, funcs in SYMMETRY.items():
        if p.endswith(suffix):
            return funcs
    return None


@register
class FateKeyTuple(Rule):
    """PR01: a ``.draw()``/``.draw_one()``/``.draw_window()`` call on the
    fate stream must pass the full key — at least (channel, round, agent,
    part); peer-addressed channels add the peer. Fewer arguments means two
    distinct messages share one fate draw and the scalar/vectorized engines
    diverge under loss."""

    id = "PR01"
    pack = "protocol"
    title = "fate draw missing part of the key tuple"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in FATE_DRAW_METHODS
            ):
                continue
            if any(isinstance(a, ast.Starred) for a in node.args):
                continue  # arity unknowable statically
            arity = len(node.args) + len([k for k in node.keywords if k.arg])
            if arity < MIN_KEY_ARITY:
                yield Finding(
                    self.id,
                    ctx.path,
                    node.lineno,
                    f".{node.func.attr}() called with {arity} key argument(s);"
                    " the fate key is (channel, round, agent, part[, peer])"
                    " — a partial key aliases distinct messages onto one fate",
                )


def _contains_size_ref(node: ast.AST) -> bool:
    """True if the subtree references an element count: a ``.size``
    attribute or any identifier containing ``size`` (``sizes``,
    ``_wsizes``, ...)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and "size" in sub.attr:
            return True
        if isinstance(sub, ast.Name) and "size" in sub.id:
            return True
    return False


def _hardcoded_width_mults(expr: ast.AST) -> Iterator[ast.BinOp]:
    """Mult nodes where one side is a bare int literal and the other side
    references an element count — i.e. ``n_elements * 4``-style byte math
    that bakes in an f32 wire width."""
    for sub in ast.walk(expr):
        if not (isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mult)):
            continue
        for const, other in ((sub.left, sub.right), (sub.right, sub.left)):
            if (
                isinstance(const, ast.Constant)
                and isinstance(const.value, int)
                and not isinstance(const.value, bool)
                and _contains_size_ref(other)
            ):
                yield sub
                break


@register
class WireBytesFromDtype(Rule):
    """PR03: wire-byte accounting — ``nbytes=`` arguments of
    ``publish()``/``send()`` and assignments to ``*bytes*`` counters — must
    derive from the payload's dtype/size (``.nbytes``, ``.itemsize``,
    ``core.wire.wire_size``), never from an element count times a hardcoded
    integer width. A literal ``* 4`` silently assumes the f32 wire format
    and misaccounts every quantized (int8) transfer."""

    id = "PR03"
    pack = "protocol"
    title = "wire bytes hardcode an element width instead of the payload dtype"

    _MSG = (
        "byte accounting multiplies an element count by a hardcoded width "
        "{w} — derive it from the payload (.nbytes/.itemsize or "
        "core.wire.wire_size) so non-f32 wire modes stay accounted"
    )

    def _width(self, mult: ast.BinOp) -> int:
        for side in (mult.left, mult.right):
            if isinstance(side, ast.Constant) and isinstance(side.value, int):
                return side.value
        return 0  # unreachable: _hardcoded_width_mults guarantees a literal

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        sinks: list = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "nbytes":
                        sinks.append(kw.value)
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in {"publish", "send"}
                    and node.args
                ):
                    sinks.append(node.args[-1])
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for tgt in targets:
                    base = tgt
                    while isinstance(base, ast.Subscript):
                        base = base.value
                    name = (
                        base.attr if isinstance(base, ast.Attribute)
                        else base.id if isinstance(base, ast.Name)
                        else ""
                    )
                    if "bytes" in name:
                        sinks.append(node.value)
                        break
        seen = set()
        for expr in sinks:
            for mult in _hardcoded_width_mults(expr):
                key = (mult.lineno, mult.col_offset)
                if key in seen:
                    continue
                seen.add(key)
                yield Finding(
                    self.id,
                    ctx.path,
                    mult.lineno,
                    self._MSG.format(w=self._width(mult)),
                )


@register
class MetricSchemaSymmetry(Rule):
    """PR04: telemetry emission sites must speak the shared metric schema.
    A ``finish_round(...)`` call must pass every schema key, as keywords,
    and nothing else — a positional argument, an unknown key, or a
    ``**kwargs`` splat is a row the byte-equality tests cannot pin; an
    ``on_channel(...)`` call naming a channel outside the schema's channel
    set creates traffic keys only one engine emits. Files declared in
    ``EMITTER_FUNCS`` that define their emitter function must still contain
    the emission call inside it."""

    id = "PR04"
    pack = "protocol"
    title = "telemetry emission site diverges from the shared metric schema"

    def _check_finish(self, ctx: FileContext, node: ast.Call) -> Iterator[Finding]:
        if node.args:
            yield Finding(
                self.id,
                ctx.path,
                node.lineno,
                "finish_round() takes schema keys as keywords only — a "
                "positional argument bypasses the schema check",
            )
        passed = set()
        for kw in node.keywords:
            if kw.arg is None:
                yield Finding(
                    self.id,
                    ctx.path,
                    node.lineno,
                    "finish_round(**kwargs) hides the emitted keys from the "
                    "schema check — pass each schema key explicitly",
                )
                return
            if kw.arg not in METRIC_FINISH_KEYS:
                yield Finding(
                    self.id,
                    ctx.path,
                    node.lineno,
                    f"finish_round() passes '{kw.arg}', which is not in the "
                    "telemetry schema (telemetry.schema.FINISH_KEYS) — one "
                    "engine would emit a row shape the others don't",
                )
            passed.add(kw.arg)
        missing = [k for k in METRIC_FINISH_KEYS if k not in passed]
        if missing:
            yield Finding(
                self.id,
                ctx.path,
                node.lineno,
                "finish_round() omits schema key(s) "
                + ", ".join(f"'{k}'" for k in missing)
                + " — every engine emits the full row every round",
            )

    def _check_channel(self, ctx: FileContext, node: ast.Call) -> Iterator[Finding]:
        cands = []
        if len(node.args) >= 2:
            cands.append(node.args[1])
        cands += [kw.value for kw in node.keywords if kw.arg == "channel"]
        for arg in cands:
            if (
                isinstance(arg, ast.Constant)
                and isinstance(arg.value, str)
                and arg.value not in METRIC_CHANNELS
            ):
                yield Finding(
                    self.id,
                    ctx.path,
                    node.lineno,
                    f"on_channel() names unknown channel '{arg.value}' — "
                    "traffic keys come from telemetry.schema.CHANNELS so "
                    "both engines emit the same columns",
                )

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        finish_fns: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
            ):
                continue
            if node.func.attr == "finish_round":
                fn = ctx.enclosing_function(node)
                if fn is not None:
                    finish_fns.add(fn.name)
                yield from self._check_finish(ctx, node)
            elif node.func.attr == "on_channel":
                yield from self._check_channel(ctx, node)

        p = _norm(ctx.path)
        for suffix, fn_name in EMITTER_FUNCS.items():
            if not p.endswith(suffix):
                continue
            defined = any(
                isinstance(n, ast.FunctionDef) and n.name == fn_name
                for n in ast.walk(ctx.tree)
            )
            if defined and fn_name not in finish_fns:
                yield Finding(
                    self.id,
                    ctx.path,
                    1,
                    f"'{fn_name}' is the declared telemetry emitter for this "
                    "engine but contains no finish_round() call — the metric "
                    "stream lost its emission site",
                )


@register
class CounterSymmetry(Rule):
    """PR02: every ``+=`` on a traffic counter must be a declared site in
    the ``SYMMETRY`` table (with its counterpart in the other engine), and
    every declared site must still exist. Flags both undeclared increments
    and stale declarations (function present, declared counter gone)."""

    id = "PR02"
    pack = "protocol"
    title = "traffic-counter site not declared in the symmetry table"

    def check(self, ctx: FileContext, options: Options) -> Iterator[Finding]:
        declared = _declared_for(ctx.path) or {}

        # actual sites: function -> counters bumped (plus finding positions)
        actual: Dict[str, Set[str]] = {}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.AugAssign):
                continue
            counter = _counter_target(node.target)
            if counter is None:
                continue
            fn = ctx.enclosing_function(node)
            fn_name = fn.name if fn is not None else "<module>"
            actual.setdefault(fn_name, set()).add(counter)
            if counter not in declared.get(fn_name, set()):
                yield Finding(
                    self.id,
                    ctx.path,
                    node.lineno,
                    f"'{counter} +=' in '{fn_name}' is not declared in "
                    "rules_protocol.SYMMETRY — declare it together with its "
                    "counterpart in the other engine",
                )

        # stale declarations: function still exists but a declared counter
        # site is gone (a wholly absent function is treated as a partial
        # file, e.g. a fixture, and skipped)
        fn_defs = {
            n.name: n for n in ast.walk(ctx.tree) if isinstance(n, ast.FunctionDef)
        }
        for fn_name, counters in declared.items():
            fn = fn_defs.get(fn_name)
            if fn is None:
                continue
            for counter in sorted(counters - actual.get(fn_name, set())):
                yield Finding(
                    self.id,
                    ctx.path,
                    fn.lineno,
                    f"SYMMETRY declares '{counter} +=' in '{fn_name}' but no "
                    "such site exists — update the table (and its mirror in "
                    "the other engine)",
                )
