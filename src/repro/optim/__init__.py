from repro.optim.optimizers import (
    Optimizer,
    OptState,
    sgd,
    momentum,
    adam,
    adamw,
    clip_by_global_norm,
    chain_clip,
)
from repro.optim.schedules import constant, cosine_warmup, linear_warmup

__all__ = [
    "Optimizer",
    "OptState",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "clip_by_global_norm",
    "chain_clip",
    "constant",
    "cosine_warmup",
    "linear_warmup",
]
