"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(peak_lr: float, warmup_steps: int):
    def sched(step):
        step = step.astype(jnp.float32)
        return peak_lr * jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))

    return sched


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return sched
