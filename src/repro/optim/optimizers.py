"""Sharded-friendly optimizers (no optax in this container; built from scratch).

Interface mirrors optax's GradientTransformation:

    opt = adam(lr_schedule)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = tree_sub(params, updates)          # or the IPLS eps-weighted apply

Design notes for the IPLS / ZeRO-1 mapping (core/sharded.py):
  * All optimizer state leaves have the SAME shape as the parameter leaf they
    belong to, so the state can be sharded with the same PartitionSpec as the
    gradient shard each data-parallel rank ("agent") owns. This is what makes
    the paper's 'responsible agent updates its own partitions' expressible as
    sharding annotations.
  * ``update`` is elementwise per leaf (no cross-leaf reductions except the
    optional global-norm clip, which is one psum-able scalar), so it runs
    unmodified on a 1/N shard of the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], tuple[Any, OptState]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        lr_t = sched(step)
        updates = jax.tree.map(lambda g: lr_t * g, grads)
        return updates, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = sched(step)
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: lr_t * (beta * m + g.astype(jnp.float32)), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: lr_t * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class AdamLeaf(NamedTuple):
    m: jax.Array
    v: jax.Array


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return jax.tree.map(
            lambda p: AdamLeaf(
                m=jnp.zeros_like(p, jnp.float32), v=jnp.zeros_like(p, jnp.float32)
            ),
            params,
        )

    def update(grads, state, params, step):
        lr_t = sched(step)
        count = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, count)
        bc2 = 1.0 - jnp.power(b2, count)

        def leaf(g, s):
            g32 = g.astype(jnp.float32)
            m = b1 * s.m + (1 - b1) * g32
            v = b2 * s.v + (1 - b2) * jnp.square(g32)
            upd = lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return upd, AdamLeaf(m=m, v=v)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        outs = [leaf(g, s) for g, s in zip(flat_g, flat_s)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_state = treedef.unflatten([o[1] for o in outs])
        return updates, new_state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1) -> Optimizer:
    base = adam(lr, b1, b2, eps)
    sched = _as_schedule(lr)

    def update(grads, state, params, step):
        updates, new_state = base.update(grads, state, params, step)
        lr_t = sched(step)
        updates = jax.tree.map(
            lambda u, p: u + lr_t * wd * p.astype(jnp.float32), updates, params
        )
        return updates, new_state

    return Optimizer(base.init, update)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: l * scale.astype(l.dtype), tree), norm


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params, step)

    return Optimizer(opt.init, update)
