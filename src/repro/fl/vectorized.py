"""Vectorized IPLS round engine: whole-round batching across agents/partitions.

The scalar engine (`fl/rounds.py`) dispatches one jitted SGD call per agent,
one numpy slice-copy per (agent, partition) message and one tiny numpy
reduction per partition — Python overhead linear in A*K per round. This
engine reproduces the *same* per-round dataflow as ONE fused device call
that batches the three round phases:

  1. local SGD for all A agents at once — agents' flat weights assembled
     into an (A, N) matrix inside the call and trained with `jax.vmap` over
     `mlp_mnist.sgd_steps_flat` (flat-space SGD, bit-identical to the tree
     scan of `sgd_steps`);
  2. aggregation of every (partition, replica-slot) instance: on TPU one
     partition-batched Pallas launch (`kernels/ipls_aggregate`) with deltas
     laid out (K_inst, R, S) + a per-instance (mask, r, eps) table; on
     CPU/GPU the identical math as K masked matmuls M @ (W - W2) that never
     materialize the delta stacks — followed by replica consensus
     (segment mean);
  3. evaluation of the (sub-sampled) agents in one vmapped call.

Only the small per-instance value tables (V_pre, V_merged, eps) cross the
device-call boundary between rounds; the (A, N) matrices live and die
inside the fused call.

Exactness: under PERFECT network conditions with a fixed membership the
scalar engine is fully deterministic — every agent sends each non-owned
partition's delta to holder `H(k)[(round + agent) % rho_k]`, holders
aggregate `w -= eps * sum(deltas)` with the eps recursion, replicas mean-
merge AFTER replies are served (so caches hold pre-merge per-replica
values), and agents assemble owned->merged / cached->pre-merge views. The
engine replicates exactly that, including per-agent data batch RNG streams,
so the two engines agree to float tolerance round by round (tested in
tests/test_vectorized.py).

Scope: PERFECT conditions, no churn (the scalar engine remains the oracle
and the only engine for lossy/churny scenarios — see docs/ENGINE.md).
Traffic accounting is computed in closed form from the partition table and
matches the scalar engine's pubsub byte counters.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import unflatten_params
from repro.kernels.ipls_aggregate.ops import aggregate_batched
from repro.models import mlp_mnist
from repro.p2p.network import PERFECT


class VectorizedIPLSSimulation:
    """Drop-in engine for `IPLSSimulation` under PERFECT/no-churn configs.

    Construction delegates to the scalar engine so the bootstrap/join
    protocol (partition transfers, donor caches, membership traffic) is
    byte-for-byte identical; the resulting state is then snapshotted into
    dense arrays and all rounds run batched.
    """

    def __init__(self, cfg, shards, x_test, y_test, use_kernel: bool | None = None):
        from repro.fl.rounds import IPLSSimulation

        # aggregation backend: the partition-batched Pallas kernel natively
        # on TPU; the identical-math XLA masked-matmul elsewhere (running the
        # kernel through the interpreter in the hot loop would be pure
        # emulation overhead — interpret mode is for correctness tests)
        self._use_kernel = (
            jax.default_backend() == "tpu" if use_kernel is None else use_kernel
        )
        if cfg.conditions != PERFECT:
            raise ValueError(
                "engine='vectorized' supports PERFECT network conditions only; "
                "use the scalar engine for lossy/delayed networks"
            )
        if cfg.churn:
            raise ValueError(
                "engine='vectorized' does not support churn schedules; "
                "use the scalar engine"
            )
        self.cfg = cfg
        self.x_test, self.y_test = x_test, y_test
        # exact init state + init-phase traffic via the scalar constructor
        seed_sim = IPLSSimulation(cfg, shards, x_test, y_test)
        self.net = seed_sim.net
        self.spec = seed_sim.spec
        self.table = seed_sim.table
        self.layout = seed_sim.layout
        self.history: List[dict] = []

        A = cfg.num_agents
        K = self.spec.num_partitions
        sizes = np.asarray(self.spec.sizes, np.int64)
        offsets = np.asarray(self.spec.offsets(), np.int64)
        N = self.spec.total
        self.A, self.K, self.N = A, K, N

        # ---- instance plane: one row per (partition, replica-slot) --------
        holders: List[List[int]] = [self.table.holders_of(k) for k in range(K)]
        inst_k: List[int] = []
        inst_owner: List[int] = []
        inst_id: Dict[Tuple[int, int], int] = {}
        for k in range(K):
            for j, h in enumerate(holders[k]):
                inst_id[(k, j)] = len(inst_k)
                inst_k.append(k)
                inst_owner.append(h)
        self.K_inst = len(inst_k)
        self._inst_k = np.asarray(inst_k, np.int32)
        self._inst_owner = np.asarray(inst_owner, np.int32)
        rho = np.asarray([len(h) for h in holders], np.int64)

        # padded instance size: tail zeros flow through the batched kernel
        # untouched (0 - eps*0), so one shared width serves all partitions
        self.S = int(sizes.max())
        self._sizes = sizes
        self._offsets = offsets

        # ---- snapshot values / eps / caches from the scalar init ----------
        V_pre = np.zeros((self.K_inst, self.S), np.float32)
        eps = np.ones((self.K_inst,), np.float32)
        for k in range(K):
            for j, h in enumerate(holders[k]):
                st = seed_sim.agents[h].owned[k]
                V_pre[inst_id[(k, j)], : sizes[k]] = st.value
                eps[inst_id[(k, j)]] = st.eps
        V_merged = np.zeros((K, self.S), np.float32)
        for k in range(K):
            V_merged[k] = V_pre[inst_id[(k, 0)]]
        owner_col = np.zeros((A, K), bool)
        for k in range(K):
            for h in holders[k]:
                owner_col[h, k] = True
        self._owner_col = owner_col

        # round-0 warm-up traffic (agents fetch partitions absent from both
        # their owned set and the donor caches left behind by joins)
        fetch_bytes = 0
        for a in range(A):
            ag = seed_sim.agents[a]
            for k in range(K):
                if k not in ag.owned and k not in ag.cache:
                    fetch_bytes += 16 + 4 * int(sizes[k])
        self._round0_fetch_bytes = fetch_bytes

        # steady-state per-round traffic: every agent updates every non-owned
        # partition (4*s_k up + 4*s_k reply) and each replica of a
        # rho_k>1 partition publishes once for consensus
        upd = int(np.sum((A - rho) * 4 * sizes))
        replica = int(np.sum(np.where(rho > 1, rho * 4 * sizes, 0)))
        self._round_bytes = 2 * upd + replica
        self._bytes_total = self.net.pubsub.total_bytes()

        # ---- per-phase routing tables (period = lcm of replication) -------
        # non-owner a targets H(k)[(round + a) % rho_k]; the pattern repeats
        # with period lcm(rho_k), so all gather/scatter index tensors are
        # precomputed once
        self._period = int(np.lcm.reduce(rho)) if len(rho) else 1
        agents_arr = np.arange(A)
        self._t_inst: List[np.ndarray] = []
        self._contrib_idx: List[np.ndarray] = []
        self._contrib_mask: List[np.ndarray] = []
        R_cap = 1
        for p in range(self._period):
            contrib: List[List[int]] = [[] for _ in range(self.K_inst)]
            t_inst = np.zeros((A, K), np.int32)
            for k in range(K):
                rk = len(holders[k])
                jsel = (p + agents_arr) % rk
                for a in range(A):
                    if owner_col[a, k]:
                        # owners read the post-consensus value: index into the
                        # merged section of the concatenated [V_pre; V_merged]
                        # value table the W-rebuild gathers from
                        t_inst[a, k] = self.K_inst + k
                    else:
                        i = inst_id[(k, int(jsel[a]))]
                        t_inst[a, k] = i
                        contrib[i].append(a)
            # owner contributes first (matches scalar pending-row order)
            rows = [[self._inst_owner[i]] + contrib[i] for i in range(self.K_inst)]
            R_cap = max(R_cap, max(len(r) for r in rows))
            self._t_inst.append(t_inst)
            self._contrib_idx.append(rows)  # ragged; padded below
        self.R_cap = R_cap
        self._contrib_M: List[np.ndarray] = []  # (K_inst, A) 0/1 contribution matrix
        for p in range(self._period):
            idx = np.zeros((self.K_inst, R_cap), np.int32)
            msk = np.zeros((self.K_inst, R_cap), np.float32)
            M = np.zeros((self.K_inst, A), np.float32)
            for i, row in enumerate(self._contrib_idx[p]):
                idx[i, : len(row)] = row
                msk[i, : len(row)] = 1.0
                M[i, row] = 1.0
            self._contrib_idx[p] = idx
            self._contrib_mask.append(msk)
            self._contrib_M.append(M)

        # ---- state carried across rounds ---------------------------------
        # only the small per-instance value tables persist; the (A, N)
        # weight matrix is an INTERNAL tensor of the fused round call (never
        # a device-call boundary buffer — at 32 agents it is ~57 MB and the
        # allocation alone costs more than the round's math)
        self._V_pre = jnp.asarray(V_pre)
        self._V_merged = jnp.asarray(V_merged)
        self._eps = jnp.asarray(eps)
        self._last_phase = self._period - 1  # any phase: all replicas equal at init

        # ---- trainers: the scalar constructor's LocalTrainer objects own
        # the per-agent RNG streams; drawing batches through their
        # draw_batch() keeps both engines' SGD inputs identical by
        # construction ----
        self._trainers = [seed_sim.trainers[a] for a in range(A)]
        bs = [min(cfg.batch_size, len(shards[a][0])) for a in range(A)]
        # contiguous buckets of equal batch size (array_split shard sizes
        # differ by at most one, so there are at most two)
        self._buckets: List[Tuple[int, int, int]] = []
        start = 0
        for a in range(1, A + 1):
            if a == A or bs[a] != bs[start]:
                self._buckets.append((start, a, bs[start]))
                start = a

        # eval subset: shared stride helper => same agents as the scalar engine
        from repro.fl.rounds import eval_subset

        self._eval_idx = np.asarray(eval_subset(list(range(A)), cfg.eval_agents), np.int32)

        self._build_jitted()

    # -- jitted batched phases ---------------------------------------------
    def _build_jitted(self):
        cfg, layout = self.cfg, self.layout
        A, K, N, S = self.A, self.K, self.N, self.S
        inst_k = jnp.asarray(self._inst_k)
        off_inst = jnp.asarray(self._offsets[self._inst_k], jnp.int32)
        size_inst = jnp.asarray(self._sizes[self._inst_k], jnp.int32)
        counts = jnp.asarray(
            np.bincount(self._inst_k, minlength=K).astype(np.float32)
        )
        offsets, sizes = self._offsets, self._sizes
        alpha = float(cfg.alpha)
        lr, iters = float(cfg.lr), int(cfg.local_iters)

        layout_t = tuple((name, tuple(shape)) for name, shape in layout)

        def _one_delta(w, x, y):
            # flat-space SGD (bit-identical to the tree scan: same GEMMs,
            # same update order) — saves the per-agent tree<->vector passes
            return w - mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t)

        use_kernel = self._use_kernel
        # instance rows grouped by partition for the masked-matmul path
        inst_of_k = [np.nonzero(self._inst_k == k)[0] for k in range(K)]
        x_te = jnp.asarray(self.x_test)
        y_te = jnp.asarray(self.y_test)
        E = len(self._eval_idx)

        def build_W(V_pre, V_merged, t_inst, rows: int):
            """Assemble ``rows`` agents' flat weights from the concatenated
            value table: owners' t_inst entries point past K_inst into the
            merged section, everyone else's at the pre-merge value of the
            replica that served their UpdateModel reply. One concatenate =
            one output pass (a dynamic_update_slice chain copies the whole
            (rows, N) buffer K times on the CPU backend)."""
            V_all = jnp.concatenate([V_pre, V_merged], axis=0)
            return jnp.concatenate(
                [V_all[t_inst[:, k], : sizes[k]] for k in range(K)], axis=1
            )

        # instance rows are k-major, so each partition's instances form a
        # contiguous row range of the (K_inst, A) contribution matrix
        inst_row0 = [int(rows[0]) if len(rows) else 0 for rows in inst_of_k]

        def round_core(V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M, t_eval):
            """Aggregation + replica consensus + eval, given the pre/post
            local-SGD weight matrices. Holder h's received-delta sum for an
            instance is the masked column reduction M @ (W - W2) over its
            partition window — computed as two GEMMs so the (A, N) delta
            matrix is never materialized."""
            # eps recursion refreshed from r BEFORE applying (paper §2.2)
            r = jnp.sum(contrib_mask, axis=1)
            eps_new = jnp.where(
                r > 0, alpha * eps + (1.0 - alpha) / jnp.maximum(r, 1.0), eps
            )
            base = V_merged[inst_k]
            if use_kernel:
                # TPU: lay the deltas out (K_inst, R, S) and aggregate every
                # (partition, replica-slot) instance in ONE kernel launch.
                # The kernel computes w - eps*masked_mean; the scalar engine
                # applies w - eps*sum, so the kernel gets eps*r.
                D = W - W2
                lane = jnp.arange(S, dtype=jnp.int32)
                valid = lane[None, :] < size_inst[:, None]      # (K_inst, S)
                col = jnp.where(valid, off_inst[:, None] + lane[None, :], 0)
                G = D[contrib_idx[:, :, None], col[:, None, :]]  # (K_inst,R,S)
                G = G * valid[:, None, :]
                V_pre = aggregate_batched(base, G, contrib_mask, eps_new * r)
            else:
                # CPU/GPU: K small masked matmuls, identical math
                V_pre = base
                for k in range(K):
                    rows = inst_of_k[k]
                    Mk = contrib_M[inst_row0[k] : inst_row0[k] + len(rows)]
                    Wk = jax.lax.dynamic_slice(W, (0, int(offsets[k])), (A, int(sizes[k])))
                    W2k = jax.lax.dynamic_slice(W2, (0, int(offsets[k])), (A, int(sizes[k])))
                    agg_k = Mk @ Wk - Mk @ W2k                   # (rho_k, s_k)
                    upd = base[rows, : sizes[k]] - eps_new[rows, None] * agg_k
                    V_pre = V_pre.at[rows, : sizes[k]].set(upd)
            # replica consensus: mean over each partition's replica slots
            V_merged_new = (
                jax.ops.segment_sum(V_pre, inst_k, num_segments=K) / counts[:, None]
            )
            # evaluate ONLY the sub-sampled agents: their assembled rows are
            # a few MB, so the full (A, N) matrix never leaves this call
            W_eval = build_W(V_pre, V_merged_new, t_eval, E)
            accs = jax.vmap(
                lambda w: mlp_mnist.evaluate(unflatten_params(w, layout), x_te, y_te)
            )(W_eval)
            return V_pre, V_merged_new, eps_new, accs

        def fused_round(V_pre, V_merged, eps, X, Y, t_prev, contrib_idx, contrib_mask, contrib_M, t_eval):
            """One whole training round in a single device call: rebuild all
            agents' weights, run every agent's local SGD, aggregate every
            partition instance, merge replicas, evaluate."""
            W = build_W(V_pre, V_merged, t_prev, A)
            W2 = jax.vmap(lambda w, x, y: mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t))(W, X, Y)
            return round_core(V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M, t_eval)

        self._build_W_j = jax.jit(build_W, static_argnums=(3,))
        self._round_core_j = jax.jit(round_core)
        self._fused_round = jax.jit(fused_round, donate_argnums=(0, 1, 2))
        self._batched_deltas_keep = jax.jit(
            lambda W, X, Y: jax.vmap(_one_delta)(W, X, Y)
        )
        # routing tables cycle with the phase; upload to device once
        self._phase_tables = [
            (
                jnp.asarray(self._contrib_idx[p]),
                jnp.asarray(self._contrib_mask[p]),
                jnp.asarray(self._contrib_M[p]),
                jnp.asarray(self._t_inst[p]),
                jnp.asarray(self._t_inst[p][self._eval_idx]),
            )
            for p in range(self._period)
        ]

    # -- one round ----------------------------------------------------------
    def _draw_batches(self):
        xs, ys = [], []
        for tr in self._trainers:
            xb, yb = tr.draw_batch()
            xs.append(xb)
            ys.append(yb)
        return xs, ys

    def run_round(self, rnd: int) -> dict:
        xs, ys = self._draw_batches()
        p = rnd % self._period
        p_prev = self._last_phase
        idx, mask, M, t_inst, t_eval = self._phase_tables[p]
        t_prev = self._phase_tables[p_prev][3]
        if len(self._buckets) == 1:
            self._V_pre, self._V_merged, self._eps, accs = self._fused_round(
                self._V_pre, self._V_merged, self._eps,
                jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
                t_prev, idx, mask, M, t_eval,
            )
        else:
            # heterogeneous batch sizes (at most two contiguous buckets from
            # array_split): assemble weights once, SGD per bucket, then the
            # shared aggregation/eval core
            W = self._build_W_j(self._V_pre, self._V_merged, t_prev, self.A)
            parts = [
                self._batched_deltas_keep(
                    W[lo:hi],
                    jnp.asarray(np.stack(xs[lo:hi])),
                    jnp.asarray(np.stack(ys[lo:hi])),
                )
                for lo, hi, _ in self._buckets
            ]
            W2 = W - jnp.concatenate(parts, axis=0)
            self._V_pre, self._V_merged, self._eps, accs = self._round_core_j(
                self._V_merged, self._eps, W, W2, idx, mask, M, t_eval
            )
        self._last_phase = p
        accs = np.asarray(accs, np.float32)

        self._bytes_total += self._round_bytes + (
            self._round0_fetch_bytes if rnd == 0 else 0
        )
        metrics = {
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "acc_max": float(accs.max()),
            "round": rnd,
            "active": self.A,
            "bytes_total": self._bytes_total,
        }
        self.history.append(metrics)
        return metrics

    def run(self) -> List[dict]:
        for rnd in range(self.cfg.rounds):
            self.run_round(rnd)
        return self.history

    # -- introspection (tests / benchmarks) ---------------------------------
    def agent_weights(self) -> np.ndarray:
        """The (A, N) matrix of per-agent assembled models, equal to what
        each scalar agent's `load_model()` would return (reconstructed from
        the value tables and the last round's routing)."""
        V_all = np.concatenate(
            [np.asarray(self._V_pre), np.asarray(self._V_merged)], axis=0
        )
        t_inst = self._t_inst[self._last_phase]
        W = np.zeros((self.A, self.N), np.float32)
        for k in range(self.K):
            off, s = self._offsets[k], self._sizes[k]
            W[:, off : off + s] = V_all[t_inst[:, k], :s]
        return W
