"""Vectorized IPLS round engine: whole-round batching across agents/partitions.

The scalar engine (`fl/rounds.py`) dispatches one jitted SGD call per agent,
one numpy slice-copy per (agent, partition) message and one tiny numpy
reduction per partition — Python overhead linear in A*K per round. This
engine reproduces the *same* per-round dataflow as ONE fused device call
that batches the three round phases:

  1. local SGD for all A agents at once — agents' flat weights assembled
     into an (A, N) matrix inside the call and trained with `jax.vmap` over
     `mlp_mnist.sgd_steps_flat` (flat-space SGD, bit-identical to the tree
     scan of `sgd_steps`);
  2. aggregation of every (partition, replica-slot) instance: on TPU one
     partition-batched Pallas launch (`kernels/ipls_aggregate`) with deltas
     laid out (K_inst, R, S) + a per-instance (mask, r, eps) table; on
     CPU/GPU the identical math as K masked matmuls M @ (W - W2) that never
     materialize the delta stacks — followed by replica consensus
     (segment mean);
  3. evaluation of the (sub-sampled) agents in one vmapped call.

Only the small per-instance value tables (V_pre, V_merged, eps) cross the
device-call boundary between rounds; the (A, N) matrices live and die
inside the fused call.

Exactness: under PERFECT network conditions with a fixed membership the
scalar engine is fully deterministic — every agent sends each non-owned
partition's delta to holder `H(k)[(round + agent) % rho_k]`, holders
aggregate `w -= eps * sum(deltas)` with the eps recursion, replicas mean-
merge AFTER replies are served (so caches hold pre-merge per-replica
values), and agents assemble owned->merged / cached->pre-merge views. The
engine replicates exactly that, including per-agent data batch RNG streams,
so the two engines agree to float tolerance round by round (tested in
tests/test_vectorized.py).

LOSSY conditions (loss_prob/delay_prob > 0) run batched too: per-message
fates come from the keyed counter-based stream (`fl/rounds.MessageFates`)
that the scalar engine's pubsub reads one message at a time, so both
engines see identical loss/delay decisions by construction. The engine
pre-draws each round's fates as (A, K) mask/delay tensors and folds them
into the contribution masks; delayed deltas ride a small ring buffer of
in-flight delta windows (depth = max delay in rounds) that feeds the
per-instance (mask, r, eps) table of the batched aggregation; lost/late
replies become cache-update masks over an explicit (A, K, S) cache plane,
so stale caches persist exactly as in the scalar engine. A tiny host-side
state machine (pure integer/boolean numpy) mirrors the scalar fetch
warm-up protocol so `bytes_total` / `messages_dropped` match the pubsub
counters exactly. See docs/ENGINE.md.

Churn: membership schedules run here too, via event-boundary re-snapshot.
Rounds between membership events run fused; each event round replays on
the embedded scalar oracle (whose `_apply_churn` implements the
leave/crash/join handoff rules), and every membership-dependent dense
structure — instance tables, `_slot_inst`/`_widx`, the value/cache/ring
planes, contribution and merge layouts, trainer buckets — is rebuilt from
the scalar state at the boundary. In-flight protocol messages cross the
boundary in both directions: harvested from the pubsub into the queue
rings and a span-constant mail plane on entry, re-injected as pubsub
messages on exit. See docs/ENGINE.md "Churn re-snapshot".
Traffic accounting is computed in closed form (PERFECT) or by the mask
stream (LOSSY) and matches the scalar engine's pubsub counters exactly.

Multi-round fusion: with ``SimConfig(scan_rounds=W)`` the engine runs
windows of W rounds as ONE ``lax.scan``-driven device call each. Batches,
fate tensors (via the windowed batch draw ``MessageFates.draw_window``)
and the host control plane are pre-drawn for the whole window; the device
state — weights plane, cache plane, delta ring, value-history rings —
lives in the fixed-shape scan carry, and the ring rotations/cache-event
gathers run as in-carry dynamic indices inside the scanned body. Per-round
bytes/messages/drops come back as stacked per-round values, still exactly
equal to the scalar pubsub counters. Evaluation is gated by
``eval_cadence`` so it can move to window boundaries. See docs/ENGINE.md
"Multi-round fused scan".
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import unflatten_params
from repro.core.wire import BLOCK as WBLOCK
from repro.core.wire import dequantize_rows, qdq_rows, quantize_rows, wire_size
from repro.kernels.ipls_aggregate.ops import aggregate_batched, aggregate_batched_q
from repro.models import mlp_mnist
from repro.telemetry.device import metric_pair

# cache-event value sources (see _run_round_lossy)
_KIND_START = 0  # holder value at the start of the serve round (fetch reply)
_KIND_AGG = 1  # holder value after aggregation, pre-merge (UpdateModel reply)
_KIND_MAIL = 2  # harvested in-flight reply payload (span-constant mail plane)


class _HarvestDeferred(Exception):
    """A span-boundary harvest met an in-flight message shape the dense
    planes cannot represent (possible only when max_delay_rounds exceeds
    one round of ticks, e.g. a straggler whose sender has since left).
    The caller replays one more round on the scalar oracle and retries —
    stragglers drain within max_delay, so the retry converges."""


class _FateWindow:
    """Per-round slices of windowed fate draws (`MessageFates.draw_window`).

    The request-side channels (fetch / UpdateModel / replica publish) have
    fixed per-round keys, so a whole scan window's (W, A, K) mask/delay
    tensors can be materialized in one hashing pass up front; the reply
    channels stay per-event draws inside the control plane (their keys
    depend on which messages actually arrived). Slices equal the per-round
    draws exactly — fates are pure hashes of their coordinates."""

    def __init__(self, fates, r0, W, a_col, k_row, rep_src_agent, rep_k, rep_dst_agent):
        from repro.fl.rounds import CH_FETCH, CH_REPLICA, CH_UPDATE

        rounds = np.arange(r0, r0 + W)
        self.r0 = r0
        self.fetch = fates.draw_window(CH_FETCH, rounds, a_col, k_row)
        self.update = fates.draw_window(CH_UPDATE, rounds, a_col, k_row)
        self.replica = (
            fates.draw_window(CH_REPLICA, rounds, rep_src_agent, rep_k, rep_dst_agent)
            if len(rep_src_agent)
            else None
        )

    def slice(self, name: str, t: int):
        de, dl = getattr(self, name)
        w = t - self.r0
        return de[w], dl[w]


class VectorizedIPLSSimulation:
    """Drop-in engine for `IPLSSimulation` under PERFECT/no-churn configs.

    Construction delegates to the scalar engine so the bootstrap/join
    protocol (partition transfers, donor caches, membership traffic) is
    byte-for-byte identical; the resulting state is then snapshotted into
    dense arrays and all rounds run batched.
    """

    def __init__(self, cfg, shards, x_test, y_test, use_kernel: bool | None = None):
        from repro.fl.rounds import IPLSSimulation

        # aggregation backend: the partition-batched Pallas kernel natively
        # on TPU; the identical-math XLA masked-matmul elsewhere (running the
        # kernel through the interpreter in the hot loop would be pure
        # emulation overhead — interpret mode is for correctness tests)
        self._use_kernel = (
            jax.default_backend() == "tpu" if use_kernel is None else use_kernel
        )
        # int8 wire mode: route through the general event-driven path even
        # under PERFECT conditions — quantized replica consensus makes each
        # holder's merged value differ (raw self + qdq of the others), which
        # the phase-table PERFECT path cannot represent; under PERFECT the
        # fate stream degenerates to (delivered, delay 0) so the event path
        # reproduces the scalar engine exactly
        self._int8 = getattr(cfg, "wire_dtype", "f32") == "int8"
        # imperfect connectivity runs batched through the mask-stream path
        # (same gate as the scalar engine's keyed-fates installation); churn
        # routes there too — membership-event rounds replay on the scalar
        # oracle and the spans between re-snapshot, which only the
        # event-driven path's queue rings can represent
        self._lossy = (
            cfg.conditions.loss_prob > 0
            or cfg.conditions.delay_prob > 0
            or self._int8
            or bool(cfg.churn)
        )
        self.cfg = cfg
        # multi-round fusion: run() executes windows of `scan_rounds` rounds
        # as one lax.scan device call each (0 = per-round calls)
        self.scan_rounds = int(getattr(cfg, "scan_rounds", 0) or 0)
        if self.scan_rounds < 0:
            raise ValueError("scan_rounds must be >= 0")
        self._eval_cadence = max(1, int(getattr(cfg, "eval_cadence", 1) or 1))
        # jitted-call counter: benchmarks report dispatches/round (the scan
        # path's whole point is driving this to 1/W)
        self.device_dispatches = 0
        self._last_accs: np.ndarray | None = None
        self.x_test, self.y_test = x_test, y_test
        # exact init state + init-phase traffic via the scalar constructor
        seed_sim = IPLSSimulation(cfg, shards, x_test, y_test)
        self.net = seed_sim.net
        # telemetry handoff: this engine emits the same per-round stream
        # through the seed's recorder, but feeds it from the control plane /
        # closed-form traffic instead of the pubsub taps — detach the pubsub
        # hook so nothing double-counts (rounds never touch the pubsub here)
        self.recorder = seed_sim.recorder
        self._pt = seed_sim._pt
        self.net.pubsub.telemetry = None
        self.spec = seed_sim.spec
        self.table = seed_sim.table
        self.layout = seed_sim.layout
        self.history: List[dict] = []

        A = cfg.num_agents
        K = self.spec.num_partitions
        sizes = np.asarray(self.spec.sizes, np.int64)
        offsets = np.asarray(self.spec.offsets(), np.int64)
        N = self.spec.total
        self.A, self.K, self.N = A, K, N
        # membership rows: live agents in scalar `active`-iteration (dict)
        # order; full fixed membership outside the churn path. The embedded
        # scalar sim stays attached as the churn replay oracle.
        self._seed = seed_sim
        self._ids: List[int] = list(range(A))
        self._n_act = A
        self._on_device = True
        self._replay: List[int] = []
        self._replay_set: frozenset = frozenset()

        # ---- instance plane: one row per (partition, replica-slot) --------
        holders: List[List[int]] = [self.table.holders_of(k) for k in range(K)]
        inst_k: List[int] = []
        inst_owner: List[int] = []
        inst_id: Dict[Tuple[int, int], int] = {}
        for k in range(K):
            for j, h in enumerate(holders[k]):
                inst_id[(k, j)] = len(inst_k)
                inst_k.append(k)
                inst_owner.append(h)
        self.K_inst = len(inst_k)
        self._inst_k = np.asarray(inst_k, np.int32)
        self._inst_owner = np.asarray(inst_owner, np.int32)
        rho = np.asarray([len(h) for h in holders], np.int64)
        self._rho = rho
        self._holder_ids = holders
        # (K, max_rho) instance id per (partition, replica slot); -1 pad
        self._slot_inst = np.full((K, int(rho.max())), -1, np.int32)
        for (k, j), i in inst_id.items():
            self._slot_inst[k, j] = i

        # padded instance size: tail zeros flow through the batched kernel
        # untouched (0 - eps*0), so one shared width serves all partitions.
        # int8 wire: round up to whole quantization blocks so each (agent,
        # partition) row of the (A, K, S) planes is an integral number of
        # scale blocks; the zero tail quantizes to zero blocks, matching the
        # scalar codec's per-slice padding exactly
        self.S = int(sizes.max())
        if self._int8:
            self.S = -(-self.S // WBLOCK) * WBLOCK
        self._sizes = sizes
        self._offsets = offsets
        # per-partition wire payload bytes (4*s for f32; s + 4*ceil(s/BLOCK)
        # for int8) — every closed-form byte count below derives from these
        self._wsizes = np.asarray(
            [wire_size(int(s), getattr(cfg, "wire_dtype", "f32")) for s in sizes],
            np.int64,
        )

        # ---- snapshot values / eps / caches from the scalar init ----------
        V_pre = np.zeros((self.K_inst, self.S), np.float32)
        eps = np.ones((self.K_inst,), np.float32)
        for k in range(K):
            for j, h in enumerate(holders[k]):
                st = seed_sim.agents[h].owned[k]
                V_pre[inst_id[(k, j)], : sizes[k]] = st.value
                eps[inst_id[(k, j)]] = st.eps
        # per-INSTANCE merged table (all replicas equal at init); each
        # holder's own sequential merge can differ by ULP at rho >= 3, so a
        # per-partition row cannot represent the scalar oracle's state
        V_merged = V_pre.copy()
        owner_col = np.zeros((A, K), bool)
        for k in range(K):
            for h in holders[k]:
                owner_col[h, k] = True
        self._owner_col = owner_col
        self._bytes_total = self.net.pubsub.total_bytes()
        # message counters mirroring the scalar pubsub (init-phase membership
        # traffic included via the snapshot; the LOSSY path keeps them exact)
        self.messages_sent = self.net.pubsub.messages_sent
        self.messages_dropped = self.net.pubsub.messages_dropped

        # ---- trainers: the scalar constructor's LocalTrainer objects own
        # the per-agent RNG streams; drawing batches through their
        # draw_batch() keeps both engines' SGD inputs identical by
        # construction ----
        self._trainers = [seed_sim.trainers[a] for a in range(A)]
        self._act_trainers = self._trainers
        bs = [min(cfg.batch_size, len(shards[a][0])) for a in range(A)]
        # contiguous buckets of equal batch size (array_split shard sizes
        # differ by at most one, so there are at most two)
        self._buckets: List[Tuple[int, int, int]] = []
        start = 0
        for a in range(1, A + 1):
            if a == A or bs[a] != bs[start]:
                self._buckets.append((start, a, bs[start]))
                start = a

        # eval subset: shared stride helper => same agents as the scalar engine
        from repro.fl.rounds import eval_subset

        self._eval_idx = np.asarray(eval_subset(list(range(A)), cfg.eval_agents), np.int32)

        if self._lossy:
            self._init_lossy(seed_sim)
            return

        # round-0 warm-up traffic (agents fetch partitions absent from both
        # their owned set and the donor caches left behind by joins)
        fetch_bytes = fetch_msgs = 0
        fetch_pairs = fetch_rep_bytes = 0
        for a in range(A):
            ag = seed_sim.agents[a]
            for k in range(K):
                if k not in ag.owned and k not in ag.cache:
                    fetch_bytes += 16 + int(self._wsizes[k])
                    fetch_msgs += 2  # the fetch and its reply
                    fetch_pairs += 1
                    fetch_rep_bytes += int(self._wsizes[k])
        self._round0_fetch_bytes = fetch_bytes
        self._round0_fetch_msgs = fetch_msgs
        # per-channel split of the same closed forms (telemetry stream)
        self._tel_r0_fetch_n = fetch_pairs
        self._tel_r0_fetch_rep_bytes = fetch_rep_bytes

        # steady-state per-round traffic: every agent updates every non-owned
        # partition (one wire payload up + one reply) and each replica of a
        # rho_k>1 partition publishes once for consensus
        upd = int(np.sum((A - rho) * self._wsizes))
        replica = int(np.sum(np.where(rho > 1, rho * self._wsizes, 0)))
        self._round_bytes = 2 * upd + replica
        self._round_msgs = 2 * int(np.sum(A - rho)) + int(np.sum(np.where(rho > 1, rho, 0)))
        # per-channel steady-state traffic (telemetry stream): one UpdateModel
        # up + one reply back per (agent, non-owned partition); each replica
        # of a rho_k>1 partition publishes once, fanning out to the rho_k-1
        # other subscribers of the partition topic
        self._tel_upd_msgs = int(np.sum(A - rho))
        self._tel_upd_bytes = upd
        self._tel_rep_msgs = int(np.sum(np.where(rho > 1, rho, 0)))
        self._tel_rep_bytes = replica
        self._tel_rep_deliv = int(np.sum(np.where(rho > 1, rho * (rho - 1), 0)))

        # ---- per-phase routing tables (period = lcm of replication) -------
        # non-owner a targets H(k)[(round + a) % rho_k]; the pattern repeats
        # with period lcm(rho_k), so all gather/scatter index tensors are
        # precomputed once
        self._period = int(np.lcm.reduce(rho)) if len(rho) else 1
        agents_arr = np.arange(A)
        self._t_inst: List[np.ndarray] = []
        self._contrib_idx: List[np.ndarray] = []
        self._contrib_mask: List[np.ndarray] = []
        R_cap = 1
        for p in range(self._period):
            contrib: List[List[int]] = [[] for _ in range(self.K_inst)]
            t_inst = np.zeros((A, K), np.int32)
            for k in range(K):
                rk = len(holders[k])
                jsel = (p + agents_arr) % rk
                for a in range(A):
                    if owner_col[a, k]:
                        # owners read their OWN replica's post-consensus value:
                        # index into the merged section of the concatenated
                        # [V_pre; V_merged] value table the W-rebuild gathers
                        # from. Merged values are per-instance, not per-
                        # partition: the scalar oracle's mean starts at the
                        # holder's own value, so at rho >= 3 each holder's
                        # merged row differs by association order.
                        t_inst[a, k] = self.K_inst + inst_id[(k, holders[k].index(a))]
                    else:
                        i = inst_id[(k, int(jsel[a]))]
                        t_inst[a, k] = i
                        contrib[i].append(a)
            # owner contributes first (matches scalar pending-row order)
            rows = [[self._inst_owner[i]] + contrib[i] for i in range(self.K_inst)]
            R_cap = max(R_cap, max(len(r) for r in rows))
            self._t_inst.append(t_inst)
            self._contrib_idx.append(rows)  # ragged; padded below
        self.R_cap = R_cap
        self._contrib_M: List[np.ndarray] = []  # (K_inst, A) 0/1 contribution matrix
        for p in range(self._period):
            idx = np.zeros((self.K_inst, R_cap), np.int32)
            msk = np.zeros((self.K_inst, R_cap), np.float32)
            M = np.zeros((self.K_inst, A), np.float32)
            for i, row in enumerate(self._contrib_idx[p]):
                idx[i, : len(row)] = row
                msk[i, : len(row)] = 1.0
                M[i, row] = 1.0
            self._contrib_idx[p] = idx
            self._contrib_mask.append(msk)
            self._contrib_M.append(M)

        # ---- replica-merge order (static under PERFECT) -------------------
        # scalar merge: np.mean over [own post-agg value] + arrivals; under
        # PERFECT the arrivals drain in publish order = holder agent
        # ascending. The sequential-sum merge must associate in exactly that
        # order, starting from the instance's own row.
        max_rho = int(rho.max()) if len(rho) else 1
        morder = np.zeros((self.K_inst, max_rho), np.int32)
        mmask = np.zeros((self.K_inst, max_rho), np.float32)
        for k in range(K):
            ids = [inst_id[(k, j)] for j in range(len(holders[k]))]
            by_agent = sorted(ids, key=lambda i: int(self._inst_owner[i]))
            for i in ids:
                row = [i] + [o for o in by_agent if o != i]
                morder[i, : len(row)] = row
                mmask[i, : len(row)] = 1.0
        self._morder_perf = morder
        self._mmask_perf = mmask

        # ---- state carried across rounds ---------------------------------
        # only the small per-instance value tables persist; the (A, N)
        # weight matrix is an INTERNAL tensor of the fused round call (never
        # a device-call boundary buffer — at 32 agents it is ~57 MB and the
        # allocation alone costs more than the round's math)
        self._V_pre = jnp.asarray(V_pre)
        self._V_merged = jnp.asarray(V_merged)
        self._eps = jnp.asarray(eps)
        self._last_phase = self._period - 1  # any phase: all replicas equal at init

        if self.recorder is not None:
            # eps replay on the host in float64: the scalar engine's eps is a
            # python float, and the device's f32 recursion drifts by an ULP —
            # the telemetry stream must carry the scalar's exact values. The
            # PERFECT contributor counts are static per routing phase.
            self._tel_eps64 = np.asarray(
                [
                    seed_sim.agents[int(self._inst_owner[i])]
                    .owned[int(self._inst_k[i])]
                    .eps
                    for i in range(self.K_inst)
                ],
                np.float64,
            )
            self._tel_r = [
                self._contrib_mask[p].sum(axis=1).astype(np.int64)
                for p in range(self._period)
            ]

        self._build_jitted()

    # -- jitted batched phases ---------------------------------------------
    def _build_jitted(self):
        cfg, layout = self.cfg, self.layout
        A, K, N, S = self.A, self.K, self.N, self.S
        inst_k = jnp.asarray(self._inst_k)
        off_inst = jnp.asarray(self._offsets[self._inst_k], jnp.int32)
        size_inst = jnp.asarray(self._sizes[self._inst_k], jnp.int32)
        counts = jnp.asarray(
            np.bincount(self._inst_k, minlength=K).astype(np.float32)
        )
        offsets, sizes = self._offsets, self._sizes
        alpha = float(cfg.alpha)
        lr, iters = float(cfg.lr), int(cfg.local_iters)

        layout_t = tuple((name, tuple(shape)) for name, shape in layout)

        def _one_delta(w, x, y):
            # flat-space SGD (bit-identical to the tree scan: same GEMMs,
            # same update order) — saves the per-agent tree<->vector passes
            return w - mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t)

        use_kernel = self._use_kernel
        # instance rows grouped by partition for the masked-matmul path
        inst_of_k = [np.nonzero(self._inst_k == k)[0] for k in range(K)]
        x_te = jnp.asarray(self.x_test)
        y_te = jnp.asarray(self.y_test)
        E = len(self._eval_idx)

        def build_W(V_pre, V_merged, t_inst, rows: int):
            """Assemble ``rows`` agents' flat weights from the concatenated
            value table: owners' t_inst entries point past K_inst into the
            merged section, everyone else's at the pre-merge value of the
            replica that served their UpdateModel reply. One concatenate =
            one output pass (a dynamic_update_slice chain copies the whole
            (rows, N) buffer K times on the CPU backend)."""
            V_all = jnp.concatenate([V_pre, V_merged], axis=0)
            return jnp.concatenate(
                [V_all[t_inst[:, k], : sizes[k]] for k in range(K)], axis=1
            )

        # instance rows are k-major, so each partition's instances form a
        # contiguous row range of the (K_inst, A) contribution matrix
        inst_row0 = [int(rows[0]) if len(rows) else 0 for rows in inst_of_k]

        morder = jnp.asarray(self._morder_perf)
        mmask_m = jnp.asarray(self._mmask_perf)
        max_rho = int(self._morder_perf.shape[1])
        rho_inst = jnp.asarray(
            np.bincount(self._inst_k, minlength=K).astype(np.float32)[self._inst_k]
        )
        R_cap = int(self.R_cap)

        def agg_merge(V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M):
            """Aggregation + replica consensus, given the pre/post local-SGD
            weight matrices. The contributor gather + sequential masked sum
            reduces each instance's deltas in the scalar oracle's pending
            order (own push first, then arrivals agent-ascending), so the
            f32 associations match the scalar engine bit-for-bit."""
            # eps recursion refreshed from r BEFORE applying (paper §2.2)
            r = jnp.sum(contrib_mask, axis=1)
            eps_new = jnp.where(
                r > 0, alpha * eps + (1.0 - alpha) / jnp.maximum(r, 1.0), eps
            )
            base = V_merged
            D = W - W2
            if use_kernel:
                # TPU: aggregate every (partition, replica-slot) instance in
                # ONE kernel launch. The kernel computes w - eps*masked_sum,
                # exactly the scalar engine's update (the 1/r lives in the
                # eps recursion).
                lane = jnp.arange(S, dtype=jnp.int32)
                valid = lane[None, :] < size_inst[:, None]   # (K_inst, S)
                col = jnp.where(valid, off_inst[:, None] + lane[None, :], 0)
                G = D[contrib_idx[:, :, None], col[:, None, :]]
                G = G * valid[:, None, :]
                V_pre = aggregate_batched(base, G, contrib_mask, eps_new)
            else:
                # CPU/GPU: per-partition static column slice + whole-row
                # gathers (memcpy-speed; an element-indexed (K_inst, R, S)
                # gather is a scalar loop on the CPU backend), reduced with
                # a sequential masked sum over the contributor slots in
                # scalar pending order, then one FMA-contracted update
                parts = []
                for k in range(K):
                    rows = inst_of_k[k]
                    if len(rows) == 0:
                        continue
                    o, sz = int(offsets[k]), int(sizes[k])
                    Dk = jax.lax.slice(D, (0, o), (A, o + sz))
                    agg_k = jnp.zeros((len(rows), sz), jnp.float32)
                    for j in range(R_cap):
                        gj = Dk[contrib_idx[rows, j]]
                        agg_k = jnp.where(
                            contrib_mask[rows, j, None] > 0, agg_k + gj, agg_k
                        )
                    parts.append(jnp.pad(agg_k, ((0, 0), (0, S - sz))))
                agg = jnp.concatenate(parts, axis=0)
                V_pre = base - eps_new[:, None] * agg
            # pin ONE materialization of V_pre: without the barrier XLA may
            # recompute it at the merge's gather site with a different FMA
            # contraction than the direct use, skewing merged rows by an ULP
            V_pre = jax.lax.optimization_barrier(V_pre)
            # replica consensus: each instance averages [self] + the other
            # replicas in arrival (holder agent ascending) order — the
            # scalar engine's np.mean associates exactly this way
            acc = V_pre
            for j in range(1, max_rho):
                acc = jnp.where(
                    mmask_m[:, j, None] > 0, acc + V_pre[morder[:, j]], acc
                )
            # barrier the (constant) divisor too: XLA folds division by a
            # constant into multiply-by-reciprocal, off by an ULP for
            # rho=3 — scalar np.mean does a true divide
            V_merged_new = acc / jax.lax.optimization_barrier(rho_inst)[:, None]
            return V_pre, V_merged_new, eps_new

        def eval_rows(V_pre, V_merged_new, t_eval):
            # evaluate ONLY the sub-sampled agents: their assembled rows are
            # a few MB, so the full (A, N) matrix never leaves this call
            W_eval = build_W(V_pre, V_merged_new, t_eval, E)
            return jax.vmap(
                lambda w: mlp_mnist.evaluate(unflatten_params(w, layout), x_te, y_te)
            )(W_eval)

        # telemetry: a python-bool trace-time gate — False leaves every
        # jitted program EXACTLY as before (no extra outputs in the jaxpr);
        # True adds one (2,) aux output per round with the f32 norm metrics
        tel = self.recorder is not None

        def round_core(V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M, t_eval):
            V_pre, V_merged_new, eps_new = agg_merge(
                V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M
            )
            out = (V_pre, V_merged_new, eps_new, eval_rows(V_pre, V_merged_new, t_eval))
            if tel:
                out = out + (metric_pair(W - W2, V_merged_new),)
            return out

        buckets = self._buckets

        def sgd_all(W, Xs, Ys):
            """All agents' local SGD on the (A, N) weight matrix; Xs/Ys are
            per-bucket stacked batches (a single bucket unless array_split
            handed out two shard sizes)."""
            step = lambda w, x, y: mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t)
            if len(buckets) == 1:
                return jax.vmap(step)(W, Xs[0], Ys[0])
            parts = [
                jax.vmap(step)(W[lo:hi], Xs[b], Ys[b])
                for b, (lo, hi, _) in enumerate(buckets)
            ]
            return jnp.concatenate(parts, axis=0)

        def fused_round(V_pre, V_merged, eps, X, Y, t_prev, contrib_idx, contrib_mask, contrib_M, t_eval):
            """One whole training round in a single device call: rebuild all
            agents' weights, run every agent's local SGD, aggregate every
            partition instance, merge replicas, evaluate."""
            W = build_W(V_pre, V_merged, t_prev, A)
            W2 = jax.vmap(lambda w, x, y: mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t))(W, X, Y)
            return round_core(V_merged, eps, W, W2, contrib_idx, contrib_mask, contrib_M, t_eval)

        def make_scan(gate_eval: bool):
            """The multi-round fused path: a window of W rounds as ONE
            device call, `lax.scan` over per-round xs (batches + routing
            tables), carry = the small value tables (V_pre, V_merged, eps).
            The scanned body is exactly `fused_round`'s math, so any W
            produces the same trajectory as W unscanned calls."""

            def body(carry, xs):
                V_pre, V_merged, eps = carry
                Xr, Yr, t_prev, idx, mask, M, t_eval, de = xs
                W = build_W(V_pre, V_merged, t_prev, A)
                W2 = sgd_all(W, Xr, Yr)
                V_pre2, V_m2, eps2 = agg_merge(V_merged, eps, W, W2, idx, mask, M)
                if gate_eval:
                    accs = jax.lax.cond(
                        de,
                        lambda: eval_rows(V_pre2, V_m2, t_eval),
                        lambda: jnp.full((E,), jnp.nan, jnp.float32),
                    )
                else:
                    accs = eval_rows(V_pre2, V_m2, t_eval)
                if tel:
                    return (V_pre2, V_m2, eps2), (accs, metric_pair(W - W2, V_m2))
                return (V_pre2, V_m2, eps2), accs

            def scan_window(V_pre, V_merged, eps, xs_all):
                carry, ys = jax.lax.scan(body, (V_pre, V_merged, eps), xs_all)
                return carry + (ys if tel else (ys,))

            return jax.jit(scan_window, donate_argnums=(0, 1, 2))

        self._build_W_j = jax.jit(build_W, static_argnums=(3,))
        self._round_core_j = jax.jit(round_core)
        self._fused_round = jax.jit(fused_round, donate_argnums=(0, 1, 2))
        self._scan_window_j = make_scan(self._eval_cadence > 1)
        self._batched_deltas_keep = jax.jit(
            lambda W, X, Y: jax.vmap(_one_delta)(W, X, Y)
        )
        # routing tables cycle with the phase; upload to device once
        self._phase_tables = [
            (
                jnp.asarray(self._contrib_idx[p]),
                jnp.asarray(self._contrib_mask[p]),
                jnp.asarray(self._contrib_M[p]),
                jnp.asarray(self._t_inst[p]),
                jnp.asarray(self._t_inst[p][self._eval_idx]),
            )
            for p in range(self._period)
        ]

    # ===================== LOSSY (mask-stream) path ========================
    def _init_lossy(self, seed_sim):
        """State for the lossy-network batched path.

        The protocol's per-parameter math stays in a handful of jitted
        batched calls per round; what loss/delay add is a tiny host-side
        control plane (integer/boolean numpy over (A, K)): the keyed fate
        stream shared with the scalar pubsub, the fetch warm-up state
        machine, and event queues for in-flight serves/arrivals/merges/
        cache updates. Delayed deltas and the value tables late messages
        read from live in small device-side history rings.

        Only membership-independent constants live here; everything shaped
        by the current membership is built by `_snapshot_from_scalar`, which
        also re-runs after every replayed membership-event round.
        """
        from repro.fl.rounds import TICKS_PER_ROUND

        cfg = self.cfg
        cond = cfg.conditions
        self._ticks = TICKS_PER_ROUND
        # delays are in tick units; a message delayed d ticks lands
        # ceil(d / TICKS) rounds late at its drain point
        self._Lu = (
            -(-cond.max_delay_rounds // TICKS_PER_ROUND) if cond.delay_prob > 0 else 0
        )
        self._HD = self._Lu + 1  # history ring depth (value ages 0..Lu)
        # in-flight event queues: bounded-depth rings indexed by
        # (consuming round) mod depth. Nothing stays in flight longer than
        # Lu rounds (delays are capped), so depth Lu+1 suffices; every slot
        # is drained exactly once per depth rounds. The window runner stacks
        # each round's drained events into dense per-round tensors that ride
        # the lax.scan as xs (the device state itself lives in the carry).
        self._qdepth = self._Lu + 1
        # int8/churn under PERFECT conditions also run this path; the scalar
        # engine never installed a fate stream there, so build one — every
        # draw degenerates to (delivered, delay 0), i.e. default delivery
        if seed_sim.fates is None:
            from repro.fl.rounds import MessageFates

            self._fates = MessageFates(cond, cfg.seed)
        else:
            self._fates = seed_sim.fates
        # membership-event rounds replay on the embedded scalar oracle; the
        # dense planes re-snapshot at each boundary (docs/ENGINE.md
        # "Churn re-snapshot")
        self._replay = sorted(
            {int(r) for r in (cfg.churn or {}) if 0 <= int(r) < cfg.rounds}
        )
        self._replay_set = frozenset(self._replay)
        # delivered-fate pubsub messages harvested at a boundary whose
        # recipient is offline: they drop at their delivery tick (round key)
        self._pending_drop_msgs: Dict[int, list] = {}
        # harvested in-flight replica values pending a version-filtered
        # merge, keyed by their merge round
        self._mail_merges: Dict[int, list] = {}
        # the constructor's membership broadcasts are still in flight; the
        # scalar ticks would deliver them during round 0, so deliver them
        # inert now — otherwise a later oracle replay would re-deliver them
        # mid-run (and drop any addressed to a then-offline agent)
        ps = seed_sim.net.pubsub
        for _i, msg in sorted(
            enumerate(ps._inflight), key=lambda e: (e[1].deliver_round, e[0])
        ):
            ps._inbox[msg.recipient].append(msg)
            ps.bytes_recv[msg.recipient] += msg.nbytes
        ps._inflight = []
        self._snapshot_from_scalar(0, harvest=False)

    def _snapshot_from_scalar(self, r0: int, harvest: bool) -> None:
        """Rebuild every membership-dependent dense structure from the
        scalar state — rows, instance tables, `_slot_inst`/`_widx`, the
        value/eps/version/cache/residual planes, closed-form traffic masks,
        replica pair tables, trainer buckets — and re-jit the span's device
        programs. Runs once at construction (harvest=False: the init-phase
        membership broadcasts were already delivered inert) and again after
        each replayed membership-event round
        (harvest=True: in-flight protocol messages are harvested into the
        delta ring / queue rings / a span-constant mail plane, so the fused
        span consumes them exactly where the scalar engine would)."""
        sim = self._seed
        ps = sim.net.pubsub
        cfg = self.cfg
        K, S = self.K, self.S
        sizes = self._sizes

        # ---- membership rows: live agents in scalar `active` (dict) order
        self._ids = [a for a, ag in sim.agents.items() if ag.live]
        A = len(self._ids)
        self.A = A
        self._row_of = {a: r for r, a in enumerate(self._ids)}
        self._ids_arr = np.asarray(self._ids, np.int64)
        self._ids_col = self._ids_arr[:, None]
        act = np.asarray([not ps.is_offline(a) for a in self._ids], bool)
        self._act = act
        self._act_idx = np.nonzero(act)[0].astype(np.int32)
        self._n_act = int(act.sum())
        self._full_active = bool(act.all())

        # ---- instance plane: one row per (partition, replica-slot) --------
        holders: List[List[int]] = [self.table.holders_of(k) for k in range(K)]
        inst_k: List[int] = []
        inst_owner_id: List[int] = []
        inst_id: Dict[Tuple[int, int], int] = {}
        for k in range(K):
            for j, h in enumerate(holders[k]):
                inst_id[(k, j)] = len(inst_k)
                inst_k.append(k)
                inst_owner_id.append(h)
        self.K_inst = len(inst_k)
        K_inst = self.K_inst
        self._inst_k = np.asarray(inst_k, np.int32)
        self._inst_owner_id = np.asarray(inst_owner_id, np.int64)
        # owner ROWS (scalar active-iteration order), the sort keys of every
        # ordered drain — after churn, dict order need not be id order
        self._inst_owner = np.asarray(
            [self._row_of[h] for h in inst_owner_id], np.int32
        )
        rho = np.asarray([len(h) for h in holders], np.int64)
        self._rho = rho
        max_rho = int(rho.max()) if len(rho) and int(rho.max()) > 0 else 1
        self._slot_inst = np.full((K, max_rho), -1, np.int32)
        for (k, j), i in inst_id.items():
            self._slot_inst[k, j] = i
        owner_col = np.zeros((A, K), bool)
        for i in range(K_inst):
            owner_col[self._inst_owner[i], self._inst_k[i]] = True
        self._owner_col = owner_col

        # sequential-reduction capacities for the ordered gather paths:
        # each other replica of a partition has at most one value in flight
        # per send round (ages 0..Lu), and each non-owner at most one
        # UpdateModel delta per in-flight send round
        self._mw = max(1, (max_rho - 1) * self._HD)
        self._cw = 1 + self._HD * (A - 1)
        # kernel-path contributor cap: owner + every other agent once per
        # delta-age window. The quantized kernel takes the owner's raw delta
        # through a dedicated input, so its contributor table holds only the
        # remote (wire) rows.
        if self._int8 and self._use_kernel:
            self.R_cap = max(1, (A - 1) * (self._Lu + 1))
        else:
            self.R_cap = 1 + (A - 1) * (self._Lu + 1)

        # per-round send counts/bytes are closed-form over ACTIVE senders:
        # loss only affects delivery, never whether a message is sent, and
        # offline agents send nothing (the scalar round skips them)
        send_mask = act[:, None] & ~owner_col & (rho > 0)[None, :]
        self._upd_send_mask = send_mask
        self._upd_msgs = int(send_mask.sum())
        self._upd_bytes = int((send_mask * self._wsizes[None, :]).sum())
        # ordered (source -> destination) instance pairs for replica sync.
        # Sources are instances whose owner is ACTIVE (offline holders skip
        # sync_replicas); destinations include offline holders — the pubsub
        # fans a publish out to every subscriber, drawing a fate each, and
        # a delivered fate to an offline holder is an offline drop at the
        # send round.
        src, dst = [], []
        for k in range(K):
            insts = np.nonzero(self._inst_k == k)[0]
            if len(insts) <= 1:
                continue
            for i in insts:
                if not act[self._inst_owner[i]]:
                    continue
                for j in insts:
                    if i != j:
                        src.append(int(i))
                        dst.append(int(j))
        self._rep_src = np.asarray(src, np.int32)
        self._rep_dst = np.asarray(dst, np.int32)
        self._rep_src_agent = self._inst_owner_id[self._rep_src]
        self._rep_dst_agent = self._inst_owner_id[self._rep_dst]
        self._rep_k = self._inst_k[self._rep_src]
        self._rep_dst_act = (
            act[self._inst_owner[self._rep_dst]]
            if len(dst)
            else np.zeros(0, bool)
        )
        pub_inst = sorted({int(i) for i in src})
        self._pub_msgs = len(pub_inst)
        self._pub_bytes = (
            int(np.sum(self._wsizes[self._inst_k[pub_inst]])) if pub_inst else 0
        )

        # W-assembly index into concat([V (K_inst rows), C (A*K rows)]):
        # owners read their own instance value, everyone else their cache row
        widx = np.zeros((A, K), np.int32)
        inst_of = {
            (int(self._inst_owner[i]), int(self._inst_k[i])): i
            for i in range(K_inst)
        }
        for r in range(A):
            for k in range(K):
                widx[r, k] = inst_of.get((r, k), K_inst + r * K + k)
        self._widx = widx

        # ---- value / eps / version / cache / residual planes --------------
        V = np.zeros((K_inst, S), np.float32)
        # eps lives on the HOST in float64: the scalar engine's per-partition
        # eps is a python float, and its recursion must be replayed in the
        # same precision (f32 replay drifts by an ULP, which the int8 codec
        # amplifies to a full quantization step).
        eps64 = np.ones(K_inst, np.float64)
        ver = np.zeros(K_inst, np.int64)
        for i in range(K_inst):
            st = sim.agents[int(self._inst_owner_id[i])].owned[int(self._inst_k[i])]
            V[i, : sizes[self._inst_k[i]]] = st.value
            eps64[i] = st.eps
            ver[i] = st.version
        self._Vl = jnp.asarray(V)
        self._eps64 = eps64
        self._ver = ver
        # explicit cache plane + fetch warm-up state. A slot stays at its
        # last successfully delivered value — exactly the scalar
        # cache-staleness semantics under loss.
        C = np.zeros((A, K, S), np.float32)
        has = np.zeros((A, K), bool)
        for r, a in enumerate(self._ids):
            for k, val in sim.agents[a].cache.items():
                C[r, k, : sizes[k]] = val
                has[r, k] = True
        self._has_cache = has
        self._C = jnp.asarray(C)
        # error-feedback residuals, one per (sender, partition) wire slice.
        # Owner positions carry the agent's (frozen, never again read)
        # residual from any pre-ownership sends — matching the scalar
        # _delta_err dict, which keeps stale entries across handoffs.
        if self._int8:
            E = np.zeros((A, K, S), np.float32)
            for r, a in enumerate(self._ids):
                for k, err in sim.agents[a]._delta_err.items():
                    if err is not None:
                        E[r, k, : len(err)] = err
        # delta ring: in-flight delta windows, one entry per delay age.
        # f32 (and the int8 CPU path) carry the (A, N) plane — for int8 the
        # rows hold the DEQUANTIZED wire values with the owner's own slices
        # kept raw; the int8 kernel path instead rings the int8 codes + the
        # per-block scale planes and dequantizes inside the fused kernel.
        if self._int8 and self._use_kernel:
            nb = S // WBLOCK
            ring_np = (
                np.zeros((self._Lu, A, K, S), np.int8),
                np.zeros((self._Lu, A, K, nb), np.float32),
            )
        else:
            ring_np = np.zeros((self._Lu, A, self.N), np.float32)
        self._serve_ring: List[list] = [[] for _ in range(self._qdepth)]
        self._arr_ring: List[list] = [[] for _ in range(self._qdepth)]
        self._cache_ring: List[list] = [[] for _ in range(self._qdepth)]
        self._merge_ring: List[list] = [[] for _ in range(self._qdepth)]
        self._seq = 0
        self._t = r0
        self._mail_merges = {}
        self._pending_drop_msgs = {}
        mail_vals: List[np.ndarray] = []
        if harvest:
            # may raise _HarvestDeferred; pubsub mutations are deferred to
            # the commit step inside, so a raise leaves the pubsub intact
            self._harvest_pubsub(r0, inst_of, ring_np, mail_vals)
        if self._int8 and self._use_kernel:
            self._ring = (jnp.asarray(ring_np[0]), jnp.asarray(ring_np[1]))
        else:
            self._ring = jnp.asarray(ring_np)
        if self._int8:
            self._E = jnp.asarray(E)
        else:
            self._E = jnp.zeros((1,), jnp.float32)
        self._Vagg_hist = jnp.zeros((self._HD, K_inst, S), jnp.float32)
        self._Vstart_hist = jnp.zeros((self._HD, K_inst, S), jnp.float32)
        # span-constant mail plane: wire images of harvested in-flight
        # reply/replica payloads, referenced by _KIND_MAIL cache events and
        # mail merge entries (the value histories the span rings start empty,
        # so pre-span values must travel alongside)
        self._V_mail = (
            np.stack(mail_vals).astype(np.float32) if mail_vals else None
        )

        # ---- trainers / batch buckets / eval rows -------------------------
        # the scalar constructor's (and _apply_churn's) LocalTrainer objects
        # own the per-agent RNG streams; drawing batches through their
        # draw_batch() keeps both engines' SGD inputs identical. Only ACTIVE
        # agents train — offline agents' streams freeze, like the scalar
        # round skipping them.
        self._trainers = [sim.trainers[a] for a in self._ids]
        self._act_trainers = [
            tr for tr, on in zip(self._trainers, act) if on
        ]
        bs = [min(cfg.batch_size, len(tr.x)) for tr in self._act_trainers]
        self._buckets = []
        start = 0
        n_act = len(bs)
        for i in range(1, n_act + 1):
            if i == n_act or bs[i] != bs[start]:
                self._buckets.append((start, i, bs[start]))
                start = i
        from repro.fl.rounds import eval_subset

        self._eval_idx = np.asarray(
            [self._row_of[a] for a in eval_subset(list(self._ids), cfg.eval_agents)],
            np.int32,
        )

        # ---- counters / telemetry handoff --------------------------------
        self.messages_sent = ps.messages_sent
        self.messages_dropped = ps.messages_dropped
        self._bytes_total = ps.total_bytes()
        ps.telemetry = None
        if harvest and (self.recorder is not None or self._eval_cadence > 1):
            # scan-gated rounds reuse the last computed accuracies; refresh
            # from the replayed round's evaluation so the reuse crosses the
            # boundary intact
            if self.recorder is not None and self.recorder.rows:
                self._last_accs = np.asarray(
                    self.recorder.rows[-1]["accs"], np.float32
                )
            else:
                self._last_accs = np.asarray(sim._eval_accs(), np.float32)
        self._build_jitted_lossy()

    def _harvest_pubsub(self, r0, inst_of, ring_np, mail_vals) -> None:
        """Convert the scalar pubsub's delivered-but-undrained inbox
        messages and its in-flight queue into span state: UpdateModel
        payloads into the delta ring + arrival entries, fetches into serve
        entries, reply/replica values into the mail plane, membership
        broadcasts delivered inert, and delivered-fate messages to offline
        recipients into pending tick-of-delivery drops.

        Classification is read-only; pubsub mutations commit at the end, so
        an unsupported straggler (`_HarvestDeferred`, only reachable when
        max_delay_rounds > TICKS_PER_ROUND) leaves the pubsub untouched for
        the scalar retry round. Within one drain slot, harvested inbox
        entries precede in-flight entries in delivery order — exactly the
        inbox fill order for max_delay_rounds <= TICKS_PER_ROUND; beyond
        that, stragglers from different source rounds may interleave with
        in-span arrivals in send order rather than delivery order."""
        from repro.core.api import (
            FETCH_TOPIC,
            REPLY_TOPIC,
            REPLICA_TOPIC,
            UPDATE_TOPIC,
        )

        sim = self._seed
        ps = sim.net.pubsub
        TICKS = self._ticks
        wire = sim.wire
        sizes, offsets = self._sizes, self._offsets
        row_of = self._row_of
        act = self._act
        Lu = self._Lu

        arr_items: list = []    # (deliver_tick, order, drain_round, entry)
        serve_items: list = []
        new_inboxes: Dict[int, list] = {}
        deliveries: list = []   # messages delivered whole (dead/member)
        order = 0

        def active_row(aid):
            r = row_of.get(aid)
            return r if (r is not None and act[r]) else None

        def pad_val(wp):
            val = np.zeros(self.S, np.float32)
            dec = wire.decode(wp)
            val[: len(dec)] = dec
            return val

        def ring_write(age, a_row, k, wp):
            if not (0 <= age < Lu):
                raise _HarvestDeferred
            if self._int8 and self._use_kernel:
                q, sc = wp
                ring_np[0][age, a_row, k, : len(q)] = q
                ring_np[1][age, a_row, k, : len(sc)] = sc
            else:
                ring_np[age, a_row, offsets[k] : offsets[k] + sizes[k]] = (
                    wire.decode(wp)
                )

        def take_update(msg, order, u):
            h_row = row_of[msg.recipient]
            k, wp = msg.payload
            i = inst_of.get((h_row, int(k)))
            if i is None:
                return  # recipient no longer owns k: scalar collect drops it
            a_row = active_row(msg.sender)
            if a_row is None:
                raise _HarvestDeferred  # sender left/offline mid-flight
            send_r = msg.sent_round // TICKS
            ring_write(r0 - send_r - 1, a_row, int(k), wp)
            arr_items.append(
                (msg.deliver_round, order, u, (send_r, a_row, int(k), int(i)))
            )

        def take_fetch(msg, order, u):
            a_row = active_row(msg.sender)
            if a_row is None:
                raise _HarvestDeferred  # requester left/offline mid-flight
            (k,) = msg.payload
            i = inst_of.get((row_of[msg.recipient], int(k)))
            if i is None:
                return  # holder lost k: scalar serve_reply returns silently
            send_r = msg.sent_round // TICKS
            serve_items.append(
                (msg.deliver_round, order, u, (send_r, a_row, int(k), int(i)))
            )

        def take_reply(msg):
            a_row = row_of[msg.recipient]
            h_row = row_of.get(msg.sender)
            if h_row is None:
                raise _HarvestDeferred  # serving holder left mid-flight
            k, wp = msg.payload
            m = len(mail_vals)
            mail_vals.append(pad_val(wp))
            dv = max(msg.deliver_round, TICKS * r0)
            self._cache_ring[(dv // TICKS) % self._qdepth].append(
                (dv, msg.sent_round, h_row, self._seq, a_row, int(k),
                 _KIND_MAIL, r0, m)
            )
            self._seq += 1

        def take_replica(msg):
            d_row = row_of[msg.recipient]
            s_row = row_of.get(msg.sender)
            if s_row is None:
                raise _HarvestDeferred  # publishing holder left mid-flight
            k, wp, ver = msg.payload
            di = inst_of.get((d_row, int(k)))
            if di is None:
                return  # no longer an owner: scalar merge filter drops it
            m = len(mail_vals)
            mail_vals.append(pad_val(wp))
            dv = max(msg.deliver_round, TICKS * r0)
            self._mail_merges.setdefault(dv // TICKS, []).append(
                (dv - 1, s_row, int(ver), int(di), m, msg.sent_round)
            )

        def lat(d):
            return -(-d // TICKS)

        # -- delivered-but-undrained inboxes of ACTIVE agents. Offline
        # agents' inboxes stay in the pubsub untouched — the scalar engine
        # would not drain them either until they come back online, which is
        # itself a membership event that replays through the oracle.
        for r, aid in enumerate(self._ids):
            if not act[r]:
                continue
            keep = []
            for msg in ps._inbox.get(aid, []):
                order += 1
                if msg.topic == UPDATE_TOPIC:
                    take_update(msg, order, r0)
                elif msg.topic == FETCH_TOPIC:
                    take_fetch(msg, order, r0)
                elif msg.topic == REPLY_TOPIC:
                    take_reply(msg)
                elif msg.topic.startswith(REPLICA_TOPIC):
                    take_replica(msg)
                else:
                    keep.append(msg)  # membership traffic: inert
            new_inboxes[aid] = keep

        # -- in-flight messages, in delivery order (ties broken by queue
        # position — the order the scalar tick appends them to an inbox)
        for _idx, msg in sorted(
            enumerate(ps._inflight), key=lambda e: (e[1].deliver_round, e[0])
        ):
            order += 1
            rrow = row_of.get(msg.recipient)
            if rrow is None:
                # dead recipient: deliver into its (never-drained) inbox
                deliveries.append(msg)
                continue
            if not act[rrow]:
                # delivered-fate message to an offline recipient: the scalar
                # tick drops it at its delivery tick
                self._pending_drop_msgs.setdefault(
                    msg.deliver_round // TICKS, []
                ).append(msg)
                continue
            send_r = msg.sent_round // TICKS
            d = msg.deliver_round - msg.sent_round
            if msg.topic == UPDATE_TOPIC:
                take_update(msg, order, send_r + lat(d))
            elif msg.topic == FETCH_TOPIC:
                take_fetch(msg, order, send_r + lat(d))
            elif msg.topic == REPLY_TOPIC:
                take_reply(msg)
            elif msg.topic.startswith(REPLICA_TOPIC):
                take_replica(msg)
            else:
                deliveries.append(msg)  # membership traffic: deliver inert

        # -- commit (no raises past this point) -----------------------------
        for aid, keep in new_inboxes.items():
            ps._inbox[aid] = keep
        for msg in deliveries:
            ps._inbox[msg.recipient].append(msg)
            ps.bytes_recv[msg.recipient] += msg.nbytes
        ps._inflight = []
        for _dv, _o, u, entry in sorted(serve_items, key=lambda e: (e[0], e[1])):
            self._serve_ring[u % self._qdepth].append(entry)
        for _dv, _o, u, entry in sorted(arr_items, key=lambda e: (e[0], e[1])):
            self._arr_ring[u % self._qdepth].append(entry)

    def _has_active(self) -> bool:
        sim = self._seed
        ps = sim.net.pubsub
        return any(
            ag.live and not ps.is_offline(a) for a, ag in sim.agents.items()
        )

    def _scalar_to_device(self, r0: int) -> bool:
        """Enter a fused span at round r0: snapshot + harvest from the
        scalar state. Returns False (staying in scalar mode for this round)
        when no agent is active or a straggler defers the harvest."""
        if not self._has_active():
            return False
        try:
            self._snapshot_from_scalar(r0, harvest=True)
        except _HarvestDeferred:
            return False
        self._on_device = True
        return True

    def _device_to_scalar(self, rnd: int) -> None:
        """Leave the fused span before replaying round `rnd` on the scalar
        oracle: write the dense device state back into the scalar agents
        (via the core/api snapshot hooks) and re-inject every pending queue
        entry as a pubsub message, so the oracle resumes from exactly the
        state the span produced."""
        from repro.core.api import (
            FETCH_TOPIC,
            REPLY_TOPIC,
            REPLICA_TOPIC,
            UPDATE_TOPIC,
        )
        from repro.fl.rounds import CH_FETCH, CH_UPDATE
        from repro.p2p.ipfs_sim import Message

        sim = self._seed
        ps = sim.net.pubsub
        TICKS = self._ticks
        wire = sim.wire
        sizes, offsets, wsizes = self._sizes, self._offsets, self._wsizes
        K, K_inst = self.K, self.K_inst
        Vl = np.asarray(self._Vl)
        Cpl = np.asarray(self._C)
        Vagg = np.asarray(self._Vagg_hist)
        Vstart = np.asarray(self._Vstart_hist)
        int8_kernel = self._int8 and self._use_kernel
        if int8_kernel:
            ring_q = np.asarray(self._ring[0])
            ring_s = np.asarray(self._ring[1])
            ring_f = None
        else:
            ring_f = np.asarray(self._ring)
        E = np.asarray(self._E) if self._int8 else None

        # ---- protocol-state writeback ------------------------------------
        for r, aid in enumerate(self._ids):
            owned = {}
            for k in range(K):
                i = self._widx[r, k]
                if i < K_inst:  # owner rows index into the instance table
                    owned[k] = (Vl[i, : sizes[k]], self._eps64[i], self._ver[i])
            cache = {
                k: Cpl[r, k, : sizes[k]]
                for k in range(K)
                if self._has_cache[r, k]
            }
            derr = (
                {k: E[r, k, : sizes[k]] for k in range(K)}
                if E is not None
                else None
            )
            sim.agents[aid].import_state(owned, cache, derr)

        # ---- pubsub clock / counters / telemetry -------------------------
        ps.round = TICKS * rnd
        ps.messages_sent = self.messages_sent
        ps.messages_dropped = self.messages_dropped
        delta_b = self._bytes_total - ps.total_bytes()
        if delta_b:
            # per-round engine traffic is tracked in aggregate; only the
            # total is observable (total_bytes sums the per-sender dict)
            ps.bytes_sent[self._ids[0]] += delta_b
        ps.telemetry = self.recorder

        # ---- re-inject pending queue entries as pubsub messages ----------
        # sort key = (send tick, phase rank, scalar within-tick order): the
        # _inflight list must hold messages in send order so the tick scan
        # delivers same-tick arrivals exactly like the scalar rounds did
        f = self._fates
        out = []
        for s in range(self._qdepth):
            for send_r, a, k, inst in self._serve_ring[s]:
                aid_req = int(self._ids_arr[a])
                _de, d = f.draw_one(CH_FETCH, send_r, aid_req, k)
                st = TICKS * send_r
                out.append(
                    ((st, 0, int(a), int(k)), st + int(d),
                     Message(FETCH_TOPIC, aid_req, (int(k),), st, st + int(d),
                             16, int(self._inst_owner_id[inst])))
                )
            for send_r, a, k, inst in self._arr_ring[s]:
                aid_snd = int(self._ids_arr[a])
                _de, d = f.draw_one(CH_UPDATE, send_r, aid_snd, k)
                st = TICKS * send_r + 2
                age = rnd - send_r - 1
                if int8_kernel:
                    # codes/scales ride the ring verbatim — re-injection is
                    # bitwise, no decode/re-encode round trip
                    nb = -(-int(sizes[k]) // WBLOCK)
                    payload = (
                        ring_q[age, a, k, : sizes[k]].copy(),
                        ring_s[age, a, k, :nb].copy(),
                    )
                else:
                    img = ring_f[age, a, offsets[k] : offsets[k] + sizes[k]]
                    payload = wire.encode_value(img)[0]
                out.append(
                    ((st, 1, int(a), int(k)), st + int(d),
                     Message(UPDATE_TOPIC, aid_snd, (int(k), payload), st,
                             st + int(d), int(wsizes[k]),
                             int(self._inst_owner_id[inst])))
                )
            for ctr, sc, holder, seq, a, k, kind, src_r, inst in self._cache_ring[s]:
                if kind == _KIND_MAIL:
                    img = self._V_mail[inst, : sizes[k]]
                elif kind == _KIND_START:
                    img = Vstart[rnd - 1 - src_r, inst, : sizes[k]]
                else:
                    img = Vagg[rnd - 1 - src_r, inst, : sizes[k]]
                out.append(
                    ((sc, 2, int(holder), seq), ctr,
                     Message(REPLY_TOPIC, int(self._ids_arr[holder]),
                             (int(k), wire.encode_value(img)[0]), sc, ctr,
                             int(wsizes[k]), int(self._ids_arr[a])))
                )
            for send_r, si, di, ver_sent, dl in self._merge_ring[s]:
                k = int(self._inst_k[si])
                img = Vagg[rnd - 1 - send_r, si, : sizes[k]]
                st = TICKS * send_r + 3
                out.append(
                    ((st, 3, int(self._inst_owner[si]), int(si)), st + int(dl),
                     Message(f"{REPLICA_TOPIC}/{k}",
                             int(self._inst_owner_id[si]),
                             (k, wire.encode_value(img)[0], int(ver_sent)),
                             st, st + int(dl), int(wsizes[k]),
                             int(self._inst_owner_id[di])))
                )
        for _u, entries in sorted(self._mail_merges.items()):
            for key_tick, src_row, ver_sent, di, m, sent_tick in entries:
                k = int(self._inst_k[di])
                img = self._V_mail[m, : sizes[k]]
                out.append(
                    ((sent_tick, 3, int(src_row), int(di)), key_tick + 1,
                     Message(f"{REPLICA_TOPIC}/{k}",
                             int(self._ids_arr[src_row]),
                             (k, wire.encode_value(img)[0], int(ver_sent)),
                             sent_tick, key_tick + 1, int(wsizes[k]),
                             int(self._inst_owner_id[di])))
                )
        for _u in sorted(self._pending_drop_msgs):
            for msg in self._pending_drop_msgs[_u]:
                out.append(((msg.sent_round, 4, 0, 0), msg.deliver_round, msg))
        out.sort(key=lambda e: e[0])
        for _key, dv, msg in out:
            if dv < TICKS * rnd:
                # already due: the scalar tick would have delivered it
                ps._inbox[msg.recipient].append(msg)
                ps.bytes_recv[msg.recipient] += msg.nbytes
            else:
                ps._inflight.append(msg)
        for ring in (self._serve_ring, self._arr_ring,
                     self._cache_ring, self._merge_ring):
            for slot in ring:
                slot.clear()
        self._mail_merges = {}
        self._pending_drop_msgs = {}
        self._on_device = False

    def _live_ids(self) -> List[int]:
        """Live agent ids in scalar iteration order — the row order of
        `agent_weights()`. Reads the oracle directly while in scalar mode
        (between an event replay and the next span)."""
        if self._lossy and not self._on_device:
            return [a for a, ag in self._seed.agents.items() if ag.live]
        return list(self._ids)

    def agent_ids(self) -> List[int]:
        return self._live_ids()

    def _build_jitted_lossy(self):
        cfg, layout = self.cfg, self.layout
        A, K, N, S, K_inst = self.A, self.K, self.N, self.S, self.K_inst
        Lu, HD = self._Lu, self._HD
        sizes, offsets = self._sizes, self._offsets
        alpha = float(cfg.alpha)
        lr, iters = float(cfg.lr), int(cfg.local_iters)
        layout_t = tuple((name, tuple(shape)) for name, shape in layout)
        LA = (Lu + 1) * A
        use_kernel = self._use_kernel
        int8 = self._int8
        CW = int(self._cw)   # contributor slots (CPU sequential-sum path)
        MW = int(self._mw)   # replica-merge slots (ordered sequential merge)
        # (A, K, S) delta-plane gather maps: row (a, k) is agent a's slice of
        # partition k, zero beyond s_k (whole zero blocks quantize to zero)
        lane_s = np.arange(S)
        valid_ks = lane_s[None, :] < sizes[:, None]
        col_ks = jnp.asarray(
            np.where(valid_ks, offsets[:, None] + lane_s[None, :], 0), jnp.int32
        )
        valid_ksf = jnp.asarray(valid_ks, jnp.float32)
        owner3 = jnp.asarray(self._owner_col)[:, :, None]
        inst_k_j = jnp.asarray(self._inst_k)
        inst_owner_j = jnp.asarray(self._inst_owner)
        WNB = S // WBLOCK if int8 else 0
        widx = jnp.asarray(self._widx)
        widx_eval = jnp.asarray(self._widx[self._eval_idx])
        # active-row structures: SGD runs over ONLINE rows only; python-level
        # branches keep every jaxpr byte-identical to the fixed-membership
        # programs when the whole membership is online
        full_active = self._full_active
        act_idx_j = jnp.asarray(self._act_idx)
        widx_act = widx if full_active else jnp.asarray(self._widx[self._act_idx])
        act3 = jnp.asarray(self._act)[:, None, None]
        # span-constant mail plane (harvested in-flight reply/replica wire
        # values); appended to each gather table only when non-empty so
        # churn-free spans keep their exact jaxprs
        MAIL = 0 if self._V_mail is None else int(self._V_mail.shape[0])
        V_mail_j = jnp.asarray(self._V_mail) if MAIL else None
        inst_of_k = [np.nonzero(self._inst_k == k)[0] for k in range(K)]
        inst_row0 = [int(rows[0]) if len(rows) else 0 for rows in inst_of_k]
        off_inst = jnp.asarray(self._offsets[self._inst_k], jnp.int32)
        size_inst = jnp.asarray(self._sizes[self._inst_k], jnp.int32)
        x_te = jnp.asarray(self.x_test)
        y_te = jnp.asarray(self.y_test)

        def build_W(V, C, idx):
            tbl = jnp.concatenate([V, C.reshape(A * K, S)], axis=0)
            return jnp.concatenate(
                [tbl[idx[:, k], : sizes[k]] for k in range(K)], axis=1
            )

        def pre(V, C, Vstart_hist, Vagg_hist, c0_mask, c0_src):
            """Phase 0: roll the start-of-round value ring, apply the cache
            updates the scalar engine would drain before LoadModel, and
            assemble all agents' flat weights. The value rings store WIRE
            values — every consumer (fetch/UpdateModel-reply cache writes,
            replica merges) saw the payload after one trip over the wire, so
            under int8 the authoritative V stays raw while the ring entry is
            its quantize->dequantize image."""
            V0 = qdq_rows(V) if int8 else V
            Vstart_new = jnp.concatenate([V0[None], Vstart_hist[:-1]], axis=0)
            parts0 = [
                Vstart_new.reshape(HD * K_inst, S),
                Vagg_hist.reshape(HD * K_inst, S),
            ]
            if MAIL:
                parts0.append(V_mail_j)
            T0 = jnp.concatenate(parts0, axis=0)
            C0 = jnp.where(c0_mask[:, :, None], T0[c0_src], C)
            W = build_W(V, C0, widx_act)
            return Vstart_new, C0, W

        def core_main(V, C0, D_now, ring, Vagg_hist, Vstart_new, E,
                      msrc, eps_new, mmask, merge_cnt, c2_mask, c2_src, kidx, kmask):
            """Phases 2-3: aggregate every (partition, replica-slot) instance
            from the current + in-flight delta windows, run the
            version-filtered replica consensus, reply-driven cache updates,
            and roll the history rings. `eps_new` is the post-recursion
            staleness weight, computed on the HOST in float64 by the control
            plane (`_control_round`) — the scalar engine's eps is a python
            float, and replaying its recursion in device f32 drifts by an
            ULP, which quantization then amplifies to a full scale step.

            int8 wire: every non-owner (a, k) delta slice is quantized (with
            the per-slice error-feedback residual E, updated at send time —
            loss-independent, exactly like the scalar encode) before joining
            the delta ring; owner slices never transit and stay raw. On the
            kernel path the ring carries the int8 codes + scale planes and
            dequantize fuses into the aggregation kernel's masked-sum; on
            the CPU path the ring carries the dequantized (A, N) plane with
            raw owner slices mixed in."""
            if int8:
                Dplane = D_now[:, col_ks] * valid_ksf[None]  # (A, K, S)
                qn, scn, ne = quantize_rows(Dplane, E)
                # offline agents never send, so their error-feedback
                # residuals must freeze exactly like the scalar dict entries
                keep3 = owner3 if full_active else (owner3 | ~act3)
                E_new = jnp.where(keep3, E, ne)
            else:
                E_new = E
            if int8 and use_kernel:
                # fused path: gather contributor CODES + SCALES per instance
                # (owner excluded from kidx by the control plane; its raw
                # delta enters through the kernel's dedicated own-input)
                Q_hist, S_hist = ring
                Q_all = jnp.concatenate([qn[None], Q_hist], axis=0).reshape(LA, K, S)
                S_all = jnp.concatenate([scn[None], S_hist], axis=0).reshape(LA, K, WNB)
                G_q = Q_all[kidx, inst_k_j[:, None]]     # (K_inst, R, S)
                G_s = S_all[kidx, inst_k_j[:, None]]     # (K_inst, R, WNB)
                d_own = Dplane[inst_owner_j, inst_k_j]   # (K_inst, S)
                V_agg = aggregate_batched_q(
                    V, d_own, G_q, G_s, kmask,
                    jnp.ones((K_inst,), jnp.float32), eps_new,
                )
                ring_new = (
                    jnp.concatenate([qn[None], Q_hist], axis=0)[:Lu],
                    jnp.concatenate([scn[None], S_hist], axis=0)[:Lu],
                )
            else:
                if int8:
                    # wire image of this round's delta plane: dequantized
                    # slices for remote readers, raw slices at owner positions
                    deq = dequantize_rows(qn, scn)
                    D_use = jnp.concatenate(
                        [
                            jnp.where(
                                owner3[:, k],
                                jax.lax.dynamic_slice(
                                    D_now, (0, int(offsets[k])), (A, int(sizes[k]))
                                ),
                                deq[:, k, : sizes[k]],
                            )
                            for k in range(K)
                        ],
                        axis=1,
                    )
                else:
                    D_use = D_now
                D_all = jnp.concatenate([D_use[None], ring], axis=0).reshape(LA, N)
                if use_kernel:
                    # TPU: gather the contributor rows (current + ring-buffer
                    # ages) into the (K_inst, R, S) layout of the batched
                    # kernel, in scalar DELIVERY order (kidx), so the kernel's
                    # sequential masked-sum associates exactly like the
                    # scalar oracle's np.sum over pending deltas
                    lane = jnp.arange(S, dtype=jnp.int32)
                    valid = lane[None, :] < size_inst[:, None]
                    col = jnp.where(valid, off_inst[:, None] + lane[None, :], 0)
                    G = D_all[kidx[:, :, None], col[:, None, :]]
                    G = G * valid[:, None, :]
                    V_agg = aggregate_batched(V, G, kmask, eps_new)
                else:
                    # CPU/GPU: per-partition static column slice + whole-row
                    # gathers of the contributor rows in scalar DELIVERY
                    # order (kidx, own delta first), reduced with a
                    # sequential masked sum, so the f32 associations match
                    # the scalar oracle's np.sum over pending deltas (an
                    # element-indexed gather is a scalar loop on CPU)
                    parts = []
                    for k in range(K):
                        rows = inst_of_k[k]
                        if len(rows) == 0:
                            continue
                        o, sz = int(offsets[k]), int(sizes[k])
                        Dk = jax.lax.slice(D_all, (0, o), (LA, o + sz))
                        agg_k = jnp.zeros((len(rows), sz), jnp.float32)
                        for j in range(CW):
                            gj = Dk[kidx[rows, j]]
                            agg_k = jnp.where(
                                kmask[rows, j, None] > 0, agg_k + gj, agg_k
                            )
                        parts.append(jnp.pad(agg_k, ((0, 0), (0, S - sz))))
                    agg = jnp.concatenate(parts, axis=0)
                    V_agg = V - eps_new[:, None] * agg
                ring_new = jnp.concatenate([D_use[None], ring], axis=0)[:Lu]
            # pin ONE materialization before the merge gathers the wire
            # image: a recompute at the gather site may pick a different FMA
            # contraction than the direct use (see the PERFECT-path barrier)
            V_agg = jax.lax.optimization_barrier(V_agg)
            # everything a post-aggregate value feeds (UpdateModel-reply
            # cache writes, replica publishes) crossed the wire: ring/table
            # the wire image, keep the authoritative V_agg raw
            V_aggw = qdq_rows(V_agg) if int8 else V_agg
            # replica consensus: mean of self + version-kept arrived values
            # (late values read the post-aggregate ring at their send age).
            # Sequential adds in the control plane's landing-tick order keep
            # the association identical to the scalar np.mean over
            # [self] + arrivals.
            Vm_flat = jnp.concatenate(
                [V_aggw[None], Vagg_hist[: HD - 1]], axis=0
            ).reshape(HD * K_inst, S)
            if MAIL:
                Vm_flat = jnp.concatenate([Vm_flat, V_mail_j], axis=0)
            acc = V_agg
            for j in range(MW):
                acc = jnp.where(mmask[:, j, None] > 0, acc + Vm_flat[msrc[:, j]], acc)
            V_new = acc / (1.0 + merge_cnt)[:, None]
            # phase-2 cache updates (may reference this round's post-agg table)
            parts2 = [
                Vstart_new.reshape(HD * K_inst, S),
                Vagg_hist.reshape(HD * K_inst, S),
                V_aggw,
            ]
            if MAIL:
                parts2.append(V_mail_j)
            T2 = jnp.concatenate(parts2, axis=0)
            C2 = jnp.where(c2_mask[:, :, None], T2[c2_src], C0)
            Vagg_hist_new = jnp.concatenate([V_aggw[None], Vagg_hist[:-1]], axis=0)
            return V_new, C2, ring_new, Vagg_hist_new, E_new

        def eval_lossy(V_new, C2):
            # evaluate the sub-sampled agents on end-of-round state
            tbl_eval = jnp.concatenate([V_new, C2.reshape(A * K, S)], axis=0)
            W_eval = jnp.concatenate(
                [tbl_eval[widx_eval[:, k], : sizes[k]] for k in range(K)], axis=1
            )
            return jax.vmap(
                lambda w: mlp_mnist.evaluate(unflatten_params(w, layout), x_te, y_te)
            )(W_eval)

        # telemetry: python-bool trace-time gate — False keeps every jitted
        # program's jaxpr unchanged; True adds the (2,) f32 norm-metric aux
        # output (deltas RAW pre-quantize, values the authoritative plane)
        tel = self.recorder is not None

        def expand_rows(D_act):
            # scatter the online rows' deltas into the full (A, N) plane;
            # offline rows stay zero (they neither send nor contribute)
            if full_active:
                return D_act
            return jnp.zeros((A, N), jnp.float32).at[act_idx_j].set(D_act)

        def core(V, C0, D_act, ring, Vagg_hist, Vstart_new, E,
                 msrc, eps, mmask, merge_cnt, c2_mask, c2_src, kidx, kmask):
            V_new, C2, ring_new, Vagg_hist_new, E_new = core_main(
                V, C0, expand_rows(D_act), ring, Vagg_hist, Vstart_new, E,
                msrc, eps, mmask, merge_cnt, c2_mask, c2_src, kidx, kmask,
            )
            accs = eval_lossy(V_new, C2)
            out = (V_new, C2, ring_new, Vagg_hist_new, E_new, accs)
            if tel:
                # delta metrics over the TRAINED rows only — the scalar
                # emission stacks exactly the active agents' deltas
                out = out + (metric_pair(D_act, V_new),)
            return out

        buckets = self._buckets
        E = len(self._eval_idx)

        def sgd_all(W, Xs, Ys):
            step = lambda w, x, y: mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t)
            if len(buckets) == 1:
                return jax.vmap(step)(W, Xs[0], Ys[0])
            parts = [
                jax.vmap(step)(W[lo:hi], Xs[b], Ys[b])
                for b, (lo, hi, _) in enumerate(buckets)
            ]
            return jnp.concatenate(parts, axis=0)

        def make_scan(gate_eval: bool):
            """Multi-round fused LOSSY path: fold pre / SGD / core into a
            single scanned body, one device call per W-round window. The
            carry is the fixed-shape device state (weights plane, cache
            plane, delta ring, value-history rings); the host control
            plane's per-round dense tensors ride as scan xs."""

            def body(carry, xs):
                V, C, ring, Vagg_hist, Vstart_hist, Eres = carry
                (Xr, Yr, c0_mask, c0_src, msrc, eps, mmask, cnt,
                 c2_mask, c2_src, kidx, kmask, de) = xs
                Vstart_new, C0, W = pre(V, C, Vstart_hist, Vagg_hist, c0_mask, c0_src)
                W2 = sgd_all(W, Xr, Yr)
                D_act = W - W2
                V_new, C2, ring_new, Vagg_hist_new, E_new = core_main(
                    V, C0, expand_rows(D_act), ring, Vagg_hist, Vstart_new, Eres,
                    msrc, eps, mmask, cnt, c2_mask, c2_src, kidx, kmask,
                )
                if gate_eval:
                    accs = jax.lax.cond(
                        de,
                        lambda: eval_lossy(V_new, C2),
                        lambda: jnp.full((E,), jnp.nan, jnp.float32),
                    )
                else:
                    accs = eval_lossy(V_new, C2)
                carry_new = (V_new, C2, ring_new, Vagg_hist_new, Vstart_new, E_new)
                if tel:
                    return carry_new, (accs, metric_pair(D_act, V_new))
                return carry_new, accs

            def scan_window(V, C, ring, Vagg_hist, Vstart_hist, Eres, xs_all):
                return jax.lax.scan(
                    body, (V, C, ring, Vagg_hist, Vstart_hist, Eres), xs_all
                )

            return jax.jit(scan_window, donate_argnums=(0, 1, 2, 3, 4, 5))

        self._lossy_pre_j = jax.jit(pre, donate_argnums=(1,))
        self._lossy_core_j = jax.jit(core, donate_argnums=(0, 1, 3, 4, 6))
        self._scan_window_j = make_scan(self._eval_cadence > 1)
        self._batched_deltas_keep = jax.jit(
            lambda W, X, Y: jax.vmap(
                lambda w, x, y: w - mlp_mnist.sgd_steps_flat(w, x, y, lr, iters, layout_t)
            )(W, X, Y)
        )

    def _push_cache_event(self, deliver_ctr, send_ctr, a, k, kind, src_round, inst):
        """Schedule a cache write for the round whose drain sees the message.
        The sort key (deliver_ctr, send_ctr, serving holder id, seq)
        reproduces the scalar inbox order — messages delivered at the same
        tick sit in send order, and within one send phase the scalar engine
        loops holders in agent-id order — so when several replies race for
        one (agent, partition) cache slot the same one wins in both engines.
        (Replies from the SAME holder in the same phase carry identical
        values, so their relative order is immaterial.)"""
        holder = int(self._inst_owner[inst])
        self._cache_ring[(deliver_ctr // self._ticks) % self._qdepth].append(
            (deliver_ctr, send_ctr, holder, self._seq, a, k, kind, src_round, inst)
        )
        self._seq += 1

    def _control_round(self, rnd: int, wf: "_FateWindow | None" = None) -> dict:
        """One round of the host-side control plane: fate draws, queue-ring
        drains, fetch warm-up state machine, traffic counters. Pure
        integer/boolean numpy over the fixed-shape event space — no device
        data — so a scan window can run it W times up front and stack the
        resulting dense tensors as `lax.scan` xs. Returns the per-round
        control tensors plus (msgs, drops, nbytes), which are exactly the
        scalar pubsub's counters for the round by construction."""
        from repro.fl.rounds import (
            CH_FETCH,
            CH_FETCH_REPLY,
            CH_REPLICA,
            CH_UPDATE,
            CH_UPDATE_REPLY,
        )

        t = self._t
        TICKS = self._ticks
        f = self._fates
        rec = self.recorder
        A, K, K_inst = self.A, self.K, self.K_inst
        Lu, HD = self._Lu, self._HD
        sizes = self._sizes
        owner = self._owner_col
        rho = self._rho
        act = self._act
        act_col = act[:, None]
        msgs = drops = nbytes = 0
        k_row = np.arange(K)[None, :]
        # routing: non-owner a targets replica slot (rnd + id_a) % rho_k —
        # keyed by the agent's ID (the scalar target rule), while every
        # dense index below runs over membership ROWS
        slot = (rnd + self._ids_col) % np.maximum(rho, 1)[None, :]
        tgt_inst = self._slot_inst[np.broadcast_to(k_row, (A, K)), slot]
        # target liveness per (a, k): a delivered-fate message to an offline
        # holder is an offline drop at the send tick (pubsub send semantics)
        tgt_act = np.zeros((A, K), bool)
        has_tgt = np.broadcast_to(rho[None, :] > 0, (A, K))
        tgt_act[has_tgt] = act[self._inst_owner[tgt_inst[has_tgt]]]

        def lat_rounds(d):
            return -(-d // TICKS)

        # ---- in-flight messages whose recipient went offline at the span
        # boundary: the scalar tick drops them at their delivery tick
        for msg in self._pending_drop_msgs.pop(t, []):
            drops += 1
            if rec is not None:
                rec.on_offline_drop(msg.deliver_round)

        # ---- phase 0: fetch requests for partitions never yet cached ------
        need = act_col & (~owner) & (~self._has_cache) & has_tgt
        n_need = int(need.sum())
        if n_need:
            de, dl = (
                wf.slice("fetch", t)
                if wf
                else f.draw(CH_FETCH, t, self._ids_col, k_row)
            )
            lost = need & ~de
            offl = need & de & ~tgt_act
            live = need & de & tgt_act
            msgs += n_need
            nbytes += 16 * n_need
            drops += int(lost.sum()) + int(offl.sum())
            if rec is not None:
                rec.on_channel(rnd, "fetch", n_need, 16 * n_need, int(lost.sum()))
                rec.on_offline_drops(rnd, int(offl.sum()))
                rec.on_delays(rnd, dl[live])
            lat = lat_rounds(dl)
            for a, k in np.argwhere(live):
                self._serve_ring[(t + int(lat[a, k])) % self._qdepth].append(
                    (t, int(a), int(k), int(tgt_inst[a, k]))
                )

        # ---- phase 1: holders serve the fetches that arrived --------------
        serves, self._serve_ring[t % self._qdepth] = (
            self._serve_ring[t % self._qdepth], []
        )
        sv_bytes = sv_drops = 0
        sv_delays: List[int] = []
        for send_r, a, k, inst in serves:
            de1, d1 = f.draw_one(
                CH_FETCH_REPLY, t, int(self._ids_arr[a]), k,
                int(self._inst_owner_id[inst]),
            )
            msgs += 1
            nbytes += int(self._wsizes[k])
            sv_bytes += int(self._wsizes[k])
            if de1:
                self._push_cache_event(
                    TICKS * t + 1 + d1, TICKS * t + 1, a, k, _KIND_START, t, inst
                )
                sv_delays.append(d1)
            else:
                drops += 1
                sv_drops += 1
        if rec is not None and serves:
            rec.on_channel(rnd, "fetch_reply", len(serves), sv_bytes, sv_drops)
            rec.on_delays(rnd, sv_delays)

        # ---- phase 2: UpdateModel sends -----------------------------------
        de_u, dl_u = (
            wf.slice("update", t)
            if wf
            else f.draw(CH_UPDATE, t, self._ids_col, k_row)
        )
        send_u = self._upd_send_mask
        msgs += self._upd_msgs
        nbytes += self._upd_bytes
        lost_u = send_u & ~de_u
        offl_u = send_u & de_u & ~tgt_act
        drops += int(lost_u.sum()) + int(offl_u.sum())
        lat_u = lat_rounds(dl_u)
        # ring appends must mirror the scalar inbox, which fills in delivery-
        # TICK order: a message delayed d ticks lands at tick TICKS*t+2+d, so
        # same-send-round arrivals drain delay-ascending first, then publish
        # (a, k) order. np.unique gives the delays sorted ascending.
        live_u = send_u & de_u & tgt_act
        if rec is not None:
            rec.on_channel(
                rnd, "update", self._upd_msgs, self._upd_bytes, int(lost_u.sum())
            )
            rec.on_offline_drops(rnd, int(offl_u.sum()))
            rec.on_delays(rnd, dl_u[live_u])
        for d in np.unique(dl_u[live_u]):
            for a, k in np.argwhere(live_u & (dl_u == d)):
                self._arr_ring[(t + int(lat_u[a, k])) % self._qdepth].append(
                    (t, int(a), int(k), int(tgt_inst[a, k]))
                )

        # ---- arrivals => contribution masks + UpdateModel replies ---------
        arrivals, self._arr_ring[t % self._qdepth] = (
            self._arr_ring[t % self._qdepth], []
        )
        M_all = np.zeros((K_inst, (Lu + 1) * A), np.float32)
        # owner self-delta — only when the owner is ONLINE (offline holders
        # neither train nor aggregate, so their r stays 0 and eps freezes)
        M_all[np.arange(K_inst), self._inst_owner] = act[self._inst_owner].astype(
            np.float32
        )
        # per-instance contributor columns in scalar DELIVERY order: the
        # arrivals list drains the ring in append order = (send round
        # ascending, then tick-delay ascending, then (a, k) send order),
        # exactly the scalar pubsub's FIFO inbox — the order the
        # sequential-sum kernel must reduce in
        contrib_cols: List[List[int]] = [[] for _ in range(K_inst)]
        for send_r, a, k, inst in arrivals:
            M_all[inst, (t - send_r) * A + a] = 1.0
            contrib_cols[inst].append((t - send_r) * A + a)
        r_vec = M_all.sum(axis=1)
        # eps recursion in float64 on the host — bit-identical to the scalar
        # engine's python-float `eps = alpha*eps + (1-alpha)/r`; the device
        # consumes only the f32 image of the post-recursion value
        r64 = np.maximum(r_vec, 1.0).astype(np.float64)  # weak f32 promotion would downgrade the divide
        self._eps64 = np.where(
            r_vec > 0,
            self.cfg.alpha * self._eps64 + (1.0 - self.cfg.alpha) / r64,
            self._eps64,
        )
        if arrivals:
            arr = np.asarray([(a, k, i) for (_, a, k, i) in arrivals], np.int64)
            de_r, d_r = f.draw(
                CH_UPDATE_REPLY, t, self._ids_arr[arr[:, 0]], arr[:, 1],
                self._inst_owner_id[arr[:, 2]],
            )
            msgs += len(arrivals)
            nbytes += int(np.sum(self._wsizes[arr[:, 1]]))
            drops += int((~de_r).sum())
            if rec is not None:
                rec.on_channel(
                    rnd, "update_reply", len(arrivals),
                    int(np.sum(self._wsizes[arr[:, 1]])), int((~de_r).sum()),
                )
                rec.on_delays(rnd, d_r[de_r])
            for j in np.nonzero(de_r)[0]:
                self._push_cache_event(
                    TICKS * t + 3 + int(d_r[j]), TICKS * t + 3,
                    int(arr[j, 0]), int(arr[j, 1]), _KIND_AGG, t, int(arr[j, 2]),
                )

        # version bumps where anything aggregated (owner always contributes
        # under fixed membership; keep the general rule anyway)
        ver_after = self._ver + (r_vec > 0).astype(np.int64)

        # ---- replica publishes --------------------------------------------
        if len(self._rep_src):
            msgs += self._pub_msgs
            nbytes += self._pub_bytes
            de_p, dl_p = (
                wf.slice("replica", t)
                if wf
                else f.draw(
                    CH_REPLICA, t, self._rep_src_agent, self._rep_k, self._rep_dst_agent
                )
            )
            lost_p = ~de_p
            offl_p = de_p & ~self._rep_dst_act
            live_p = de_p & self._rep_dst_act
            drops += int(lost_p.sum()) + int(offl_p.sum())
            if rec is not None:
                rec.on_channel(
                    rnd, "replica", self._pub_msgs, self._pub_bytes,
                    int(lost_p.sum()),
                )
                rec.on_offline_drops(rnd, int(offl_p.sum()))
                rec.on_delays(rnd, dl_p[live_p])
            lat_p = lat_rounds(dl_p)
            for j in np.nonzero(live_p)[0]:
                si, di = int(self._rep_src[j]), int(self._rep_dst[j])
                self._merge_ring[(t + int(lat_p[j])) % self._qdepth].append(
                    (t, si, di, int(ver_after[si]), int(dl_p[j]))
                )

        # ---- merge set: version-filtered replica values due this round ----
        # ordered columns into the flattened (HD*K_inst) value-history table,
        # sorted by landing tick (then source agent) = the scalar inbox's
        # FIFO drain order, so the device's sequential merge associates
        # exactly like the scalar oracle's np.mean over [self] + arrivals
        MW = self._mw
        msrc = np.zeros((K_inst, MW), np.int32)
        mmsk = np.zeros((K_inst, MW), np.float32)
        cnt = np.zeros(K_inst, np.float32)
        merges, self._merge_ring[t % self._qdepth] = (
            self._merge_ring[t % self._qdepth], []
        )
        # unified landing-order key over in-span and harvested (mail) merge
        # entries: (landing tick - 1, send tick, source row). In-span
        # entries publish at tick TICKS*send_r + 3 and land at +3 + dl;
        # under max_delay <= TICKS the send-tick component is a no-op (all
        # same-landing-tick entries share the send round), beyond that it
        # keeps stragglers in scalar send order.
        entries = [
            (
                e[0] * TICKS + 2 + e[4], e[0] * TICKS + 3,
                int(self._inst_owner[e[1]]), int(e[2]), int(e[3]),
                (t - e[0]) * K_inst + int(e[1]),
            )
            for e in merges
        ] + [
            (int(kt), int(st_), int(sr), int(di), int(vs), HD * K_inst + int(m))
            for kt, sr, vs, di, m, st_ in self._mail_merges.pop(t, [])
        ]
        entries.sort(key=lambda e: (e[0], e[1], e[2]))
        for _kt, _st, _sr, di, ver_sent, col_src in entries:
            if ver_sent >= ver_after[di]:
                col = int(cnt[di])
                msrc[di, col] = col_src
                mmsk[di, col] = 1.0
                cnt[di] += 1.0
        self._ver = ver_after

        # ---- cache-update batches (phase-0 / phase-2 drains) --------------
        c0_mask = np.zeros((A, K), bool)
        c0_src = np.zeros((A, K), np.int32)
        c2_mask = np.zeros((A, K), bool)
        c2_src = np.zeros((A, K), np.int32)
        cache_events, self._cache_ring[t % self._qdepth] = (
            self._cache_ring[t % self._qdepth], []
        )
        for ctr, _sc, _holder, _seq, a, k, kind, src_r, inst in sorted(cache_events):
            is_c0 = ctr % TICKS <= 1
            if kind == _KIND_MAIL:
                # harvested reply payload: `inst` is a mail-plane row; the
                # mail block sits after the value sections of each gather
                # table (T0: 2 history rings; T2: rings + this round's
                # post-agg table)
                idx = (
                    2 * HD * K_inst + inst
                    if is_c0
                    else (2 * HD + 1) * K_inst + inst
                )
            elif kind == _KIND_START:
                idx = (t - src_r) * K_inst + inst
            elif src_r < t:
                idx = HD * K_inst + (t - src_r - 1) * K_inst + inst
            else:
                idx = 2 * HD * K_inst + inst
            if is_c0:
                c0_mask[a, k] = True
                c0_src[a, k] = idx
            else:
                c2_mask[a, k] = True
                c2_src[a, k] = idx
            self._has_cache[a, k] = True  # suppresses fetches from round t+1

        # ---- contributor gathers (kernel + CPU sequential-sum paths) ------
        # slot order IS reduction order for the sequential sum, so it must
        # be the scalar pending order: own delta first (the local push
        # precedes the inbox drain), then arrivals in delivery order. The
        # quantized kernel takes the owner's raw delta through a dedicated
        # input summed first, so its table holds only the remote rows; the
        # CPU path gathers from the wire-image delta plane, where the
        # owner's raw slice is already mixed in.
        if self._use_kernel:
            width = self.R_cap
            add_owner = not self._int8
        else:
            width = self._cw
            add_owner = True
        kidx = np.zeros((K_inst, width), np.int32)
        kmask = np.zeros((K_inst, width), np.float32)
        for i in range(K_inst):
            rows = contrib_cols[i]
            # offline owners contribute nothing (their D row is zero anyway,
            # but keeping the mask exact keeps the sequential-sum shape
            # aligned with the scalar pending order)
            if add_owner and act[self._inst_owner[i]]:
                rows = [int(self._inst_owner[i])] + rows
            kidx[i, : len(rows)] = rows
            kmask[i, : len(rows)] = 1.0

        self._t = t + 1
        ctl = dict(
            rnd=rnd, c0_mask=c0_mask, c0_src=c0_src, c2_mask=c2_mask,
            c2_src=c2_src, msrc=msrc, eps=self._eps64.astype(np.float32),
            mmask=mmsk, cnt=cnt, kidx=kidx, kmask=kmask,
            msgs=msgs, drops=drops, nbytes=nbytes,
        )
        if rec is not None:
            # snapshots for the round's finish_round emission: contributor
            # counts and the post-recursion f64 eps (self._eps64 mutates
            # every round, so the window runner needs per-round copies)
            ctl["r_vec"] = r_vec.astype(np.int64)
            ctl["eps64"] = self._eps64.copy()
        return ctl

    def _run_round_lossy(self, rnd: int) -> dict:
        pt = self._pt
        with pt.phase("control"):
            ctl = self._control_round(rnd)

        # ---- device calls -------------------------------------------------
        with pt.phase("batches"):
            xs, ys = self._draw_batches()
        with pt.phase("device_pre"):
            Vstart_new, C0, W = self._lossy_pre_j(
                self._Vl, self._C, self._Vstart_hist, self._Vagg_hist,
                jnp.asarray(ctl["c0_mask"]), jnp.asarray(ctl["c0_src"]),
            )
            if pt.sync:
                jax.block_until_ready(W)
        with pt.phase("device_sgd"):
            if len(self._buckets) == 1:
                D_now = self._batched_deltas_keep(
                    W, jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))
                )
            else:
                parts = [
                    self._batched_deltas_keep(
                        W[lo:hi],
                        jnp.asarray(np.stack(xs[lo:hi])),
                        jnp.asarray(np.stack(ys[lo:hi])),
                    )
                    for lo, hi, _ in self._buckets
                ]
                D_now = jnp.concatenate(parts, axis=0)
            if pt.sync:
                jax.block_until_ready(D_now)
        with pt.phase("device_core"):
            out = self._lossy_core_j(
                self._Vl, C0, D_now, self._ring, self._Vagg_hist,
                Vstart_new, self._E, jnp.asarray(ctl["msrc"]),
                jnp.asarray(ctl["eps"]),
                jnp.asarray(ctl["mmask"]), jnp.asarray(ctl["cnt"]),
                jnp.asarray(ctl["c2_mask"]), jnp.asarray(ctl["c2_src"]),
                jnp.asarray(ctl["kidx"]), jnp.asarray(ctl["kmask"]),
            )
            if pt.sync:
                jax.block_until_ready(out)
        met = None
        if self.recorder is not None:
            (
                self._Vl, self._C, self._ring, self._Vagg_hist,
                self._E, accs, met,
            ) = out
        else:
            (
                self._Vl, self._C, self._ring, self._Vagg_hist,
                self._E, accs,
            ) = out
        self._Vstart_hist = Vstart_new
        self.device_dispatches += 2 + len(self._buckets)

        self.messages_sent += ctl["msgs"]
        self.messages_dropped += ctl["drops"]
        self._bytes_total += ctl["nbytes"]
        metrics = self._metrics_entry(rnd, np.asarray(accs, np.float32))
        self.history.append(metrics)
        if self.recorder is not None:
            self._emit_row(rnd, ctl["r_vec"], ctl["eps64"], met)
        return metrics

    def _run_window_lossy(self, r0: int, W: int) -> None:
        """W LOSSY rounds as one lax.scan device call: run the host control
        plane W times up front (windowed fate draws where the keys are
        fixed), stack its dense per-round tensors as scan xs, and scan the
        fused pre+SGD+core body over them with the device state in the
        carry."""
        K = self.K
        pt = self._pt
        with pt.phase("fate_draw"):
            wf = _FateWindow(
                self._fates, self._t, W, self._ids_col, np.arange(K)[None, :],
                self._rep_src_agent, self._rep_k, self._rep_dst_agent,
            )
        with pt.phase("control"):
            ctls = [self._control_round(r0 + w, wf) for w in range(W)]
        with pt.phase("batches"):
            Xw, Yw = [], []
            for _ in range(W):
                xs, ys = self._draw_batches()
                Xw.append(xs)
                Yw.append(ys)
        Xs = tuple(
            jnp.asarray(np.stack([np.stack(Xw[w][lo:hi]) for w in range(W)]))
            for lo, hi, _ in self._buckets
        )
        Ys = tuple(
            jnp.asarray(np.stack([np.stack(Yw[w][lo:hi]) for w in range(W)]))
            for lo, hi, _ in self._buckets
        )
        stack = lambda key: jnp.asarray(np.stack([c[key] for c in ctls]))
        des = jnp.asarray([self._do_eval(r0 + w) for w in range(W)])
        xs_all = (
            Xs, Ys, stack("c0_mask"), stack("c0_src"), stack("msrc"),
            stack("eps"), stack("mmask"), stack("cnt"), stack("c2_mask"),
            stack("c2_src"), stack("kidx"), stack("kmask"), des,
        )
        with pt.phase("device_window"):
            carry, ys = self._scan_window_j(
                self._Vl, self._C, self._ring, self._Vagg_hist,
                self._Vstart_hist, self._E, xs_all,
            )
            if pt.sync:
                jax.block_until_ready(ys)
        (
            self._Vl, self._C, self._ring, self._Vagg_hist,
            self._Vstart_hist, self._E,
        ) = carry
        self.device_dispatches += 1
        mets = None
        if self.recorder is not None:
            accs, mets = ys
            mets = np.asarray(mets, np.float32)
        else:
            accs = ys
        accs = np.asarray(accs, np.float32)
        for w in range(W):
            c = ctls[w]
            self.messages_sent += c["msgs"]
            self.messages_dropped += c["drops"]
            self._bytes_total += c["nbytes"]
            self.history.append(self._metrics_entry(r0 + w, accs[w]))
            if self.recorder is not None:
                self._emit_row(r0 + w, c["r_vec"], c["eps64"], mets[w])

    # -- one round ----------------------------------------------------------
    def _draw_batches(self):
        # only the ONLINE agents' RNG streams advance — the scalar train
        # phase skips offline agents, so their trainers must not draw
        xs, ys = [], []
        for tr in self._act_trainers:
            xb, yb = tr.draw_batch()
            xs.append(xb)
            ys.append(yb)
        return xs, ys

    def _scalar_round(self, rnd: int) -> dict:
        """One round on the embedded scalar oracle: membership-event rounds
        (and the rare spans the dense planes cannot host, e.g. zero active
        agents) replay there, then the next fused round re-snapshots."""
        if self._on_device:
            self._device_to_scalar(rnd)
        met = self._seed.run_round(rnd)
        # keep the mirrored counters live even if the run ends on the oracle
        ps = self.net.pubsub
        self.messages_sent = ps.messages_sent
        self.messages_dropped = ps.messages_dropped
        self._bytes_total = ps.total_bytes()
        self._n_act = met["active"]
        self.history.append(met)
        return met

    def run_round(self, rnd: int) -> dict:
        if self._lossy:
            if rnd in self._replay_set:
                return self._scalar_round(rnd)
            if not self._on_device and not self._scalar_to_device(rnd):
                return self._scalar_round(rnd)
            return self._run_round_lossy(rnd)
        pt = self._pt
        with pt.phase("batches"):
            xs, ys = self._draw_batches()
        p = rnd % self._period
        p_prev = self._last_phase
        idx, mask, M, t_inst, t_eval = self._phase_tables[p]
        t_prev = self._phase_tables[p_prev][3]
        with pt.phase("device_round"):
            if len(self._buckets) == 1:
                out = self._fused_round(
                    self._V_pre, self._V_merged, self._eps,
                    jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
                    t_prev, idx, mask, M, t_eval,
                )
            else:
                # heterogeneous batch sizes (at most two contiguous buckets
                # from array_split): assemble weights once, SGD per bucket,
                # then the shared aggregation/eval core
                W = self._build_W_j(self._V_pre, self._V_merged, t_prev, self.A)
                parts = [
                    self._batched_deltas_keep(
                        W[lo:hi],
                        jnp.asarray(np.stack(xs[lo:hi])),
                        jnp.asarray(np.stack(ys[lo:hi])),
                    )
                    for lo, hi, _ in self._buckets
                ]
                W2 = W - jnp.concatenate(parts, axis=0)
                out = self._round_core_j(
                    self._V_merged, self._eps, W, W2, idx, mask, M, t_eval
                )
            if pt.sync:
                jax.block_until_ready(out)
        met = None
        if self.recorder is not None:
            self._V_pre, self._V_merged, self._eps, accs, met = out
        else:
            self._V_pre, self._V_merged, self._eps, accs = out
        self.device_dispatches += 1 if len(self._buckets) == 1 else 2 + len(self._buckets)
        self._last_phase = p
        accs = np.asarray(accs, np.float32)

        self._perfect_traffic(rnd)
        metrics = self._metrics_entry(rnd, accs)
        self.history.append(metrics)
        if self.recorder is not None:
            self._emit_perfect(rnd, met)
        return metrics

    def _perfect_traffic(self, rnd: int) -> None:
        self._bytes_total += self._round_bytes + (
            self._round0_fetch_bytes if rnd == 0 else 0
        )
        # keep the pubsub-mirroring counters live on the PERFECT path too
        # (nothing drops under PERFECT conditions)
        self.messages_sent += self._round_msgs + (
            self._round0_fetch_msgs if rnd == 0 else 0
        )

    def _emit_row(self, rnd: int, contrib, eps, met) -> None:
        """The engine's single telemetry emission site: one schema-ordered
        finish_round per round, from the device aux metrics + control-plane
        snapshots. Shapes/float paths mirror the scalar engine exactly
        (byte-identical rows; tests/test_telemetry.py)."""
        m = np.asarray(met, np.float32)
        self.recorder.finish_round(
            round=rnd,
            active=self._n_act,
            contrib=[int(x) for x in contrib],
            eps=[float(x) for x in eps],
            delta_normsq=float(m[0]),
            value_normsq=float(m[1]),
            accs=self._last_accs,
            bytes_total=self._bytes_total,
            msgs_total=self.messages_sent,
            drops_total=self.messages_dropped,
        )

    def _emit_perfect(self, rnd: int, met) -> None:
        """PERFECT-path telemetry: the closed-form traffic split by channel
        (everything delivered, delay 0; replica publishes fan out rho_k-1
        ways), plus the host-f64 eps replay of the scalar recursion."""
        rec = self.recorder
        if rnd == 0 and self._tel_r0_fetch_n:
            n = self._tel_r0_fetch_n
            rec.on_channel(rnd, "fetch", n, 16 * n, 0)
            rec.on_delivered(rnd, 0, n)
            rec.on_channel(rnd, "fetch_reply", n, self._tel_r0_fetch_rep_bytes, 0)
            rec.on_delivered(rnd, 0, n)
        rec.on_channel(rnd, "update", self._tel_upd_msgs, self._tel_upd_bytes, 0)
        rec.on_delivered(rnd, 0, self._tel_upd_msgs)
        rec.on_channel(
            rnd, "update_reply", self._tel_upd_msgs, self._tel_upd_bytes, 0
        )
        rec.on_delivered(rnd, 0, self._tel_upd_msgs)
        if self._tel_rep_msgs:
            rec.on_channel(
                rnd, "replica", self._tel_rep_msgs, self._tel_rep_bytes, 0
            )
            rec.on_delivered(rnd, 0, self._tel_rep_deliv)
        r = self._tel_r[rnd % self._period]
        self._tel_eps64 = (
            self.cfg.alpha * self._tel_eps64 + (1.0 - self.cfg.alpha) / r
        )
        self._emit_row(rnd, r, self._tel_eps64, met)

    def _do_eval(self, rnd: int) -> bool:
        """Scanned-mode eval gate: every `eval_cadence`-th round plus the
        final round of the run."""
        return (rnd + 1) % self._eval_cadence == 0 or rnd == self.cfg.rounds - 1

    def _metrics_entry(self, rnd: int, accs: np.ndarray) -> dict:
        """History entry for one round; rounds the scanned path skipped
        (eval_cadence > 1 => NaN accs out of the cond) reuse the last
        computed accuracies, so the history schema never changes."""
        if np.isnan(accs).all():
            accs = (
                self._last_accs
                if self._last_accs is not None
                else np.zeros_like(accs)
            )
        else:
            self._last_accs = accs
        return {
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "acc_max": float(accs.max()),
            "round": rnd,
            "active": self._n_act,
            "bytes_total": self._bytes_total,
        }

    def _run_window_perfect(self, r0: int, W: int) -> None:
        """W PERFECT rounds as one lax.scan device call: batches and the
        phase-cycled routing tables are stacked as (W, ...) scan xs, the
        value tables (V_pre, V_merged, eps) ride the carry."""
        # pre-draw the whole window's batches through the trainers' rng
        # streams — round-major order, so the streams advance exactly as in
        # the unscanned path
        pt = self._pt
        with pt.phase("batches"):
            Xw, Yw = [], []
            for _ in range(W):
                xs, ys = self._draw_batches()
                Xw.append(xs)
                Yw.append(ys)
        Xs = tuple(
            jnp.asarray(np.stack([np.stack(Xw[w][lo:hi]) for w in range(W)]))
            for lo, hi, _ in self._buckets
        )
        Ys = tuple(
            jnp.asarray(np.stack([np.stack(Yw[w][lo:hi]) for w in range(W)]))
            for lo, hi, _ in self._buckets
        )
        prev = self._last_phase
        t_prev_l, idx_l, mask_l, M_l, t_eval_l, de_l = [], [], [], [], [], []
        for w in range(W):
            rnd = r0 + w
            p = rnd % self._period
            t_prev_l.append(self._t_inst[prev])
            idx_l.append(self._contrib_idx[p])
            mask_l.append(self._contrib_mask[p])
            M_l.append(self._contrib_M[p])
            t_eval_l.append(self._t_inst[p][self._eval_idx])
            de_l.append(self._do_eval(rnd))
            prev = p
        xs_all = (
            Xs, Ys,
            jnp.asarray(np.stack(t_prev_l)), jnp.asarray(np.stack(idx_l)),
            jnp.asarray(np.stack(mask_l)), jnp.asarray(np.stack(M_l)),
            jnp.asarray(np.stack(t_eval_l)), jnp.asarray(np.asarray(de_l, bool)),
        )
        with pt.phase("device_window"):
            out = self._scan_window_j(
                self._V_pre, self._V_merged, self._eps, xs_all
            )
            if pt.sync:
                jax.block_until_ready(out)
        mets = None
        if self.recorder is not None:
            self._V_pre, self._V_merged, self._eps, accs, mets = out
            mets = np.asarray(mets, np.float32)
        else:
            self._V_pre, self._V_merged, self._eps, accs = out
        self.device_dispatches += 1
        self._last_phase = prev
        accs = np.asarray(accs, np.float32)
        for w in range(W):
            self._perfect_traffic(r0 + w)
            self.history.append(self._metrics_entry(r0 + w, accs[w]))
            if self.recorder is not None:
                self._emit_perfect(r0 + w, mets[w])

    def run_window(self, start_rnd: int, window: int) -> List[dict]:
        """Run `window` consecutive rounds as ONE lax.scan-driven device
        call (the multi-round fused path; see docs/ENGINE.md). Returns the
        new history entries — one per round, bytes/messages/drops accounted
        per round exactly as the scalar pubsub would."""
        if window < 1:
            raise ValueError("window must be >= 1")
        n0 = len(self.history)
        if self._lossy:
            # a window may not span a membership event: fall back to
            # round-at-a-time (which replays event rounds on the oracle)
            ok = not any(
                (start_rnd + w) in self._replay_set for w in range(window)
            )
            if ok and not self._on_device:
                ok = self._scalar_to_device(start_rnd)
            if ok:
                self._run_window_lossy(start_rnd, window)
            else:
                for w in range(window):
                    self.run_round(start_rnd + w)
        else:
            self._run_window_perfect(start_rnd, window)
        return self.history[n0:]

    def run(self) -> List[dict]:
        W = self.scan_rounds
        R = self.cfg.rounds
        if W:
            rnd = 0
            while rnd < R:
                if rnd in self._replay_set:
                    # membership event: replay this round on the oracle,
                    # then resume fused windows after the re-snapshot
                    self.run_round(rnd)
                    rnd += 1
                    continue
                nxt = next((r for r in self._replay if r > rnd), R)
                step = min(W, nxt - rnd)
                self.run_window(rnd, step)
                rnd += step
        else:
            for rnd in range(R):
                self.run_round(rnd)
        return self.history

    # -- introspection (tests / benchmarks) ---------------------------------
    def agent_weights(self) -> np.ndarray:
        """The (A, N) matrix of per-agent assembled models, equal to what
        each scalar agent's `load_model()` would return (reconstructed from
        the value tables and the last round's routing)."""
        if self._lossy:
            if not self._on_device:
                # state currently lives on the scalar oracle (mid-churn)
                ids = self._live_ids()
                W = np.zeros((len(ids), self.N), np.float32)
                for r, a in enumerate(ids):
                    W[r] = self._seed.agents[a].load_model()
                return W
            tbl = np.concatenate(
                [
                    np.asarray(self._Vl),
                    np.asarray(self._C).reshape(self.A * self.K, self.S),
                ],
                axis=0,
            )
            W = np.zeros((self.A, self.N), np.float32)
            for k in range(self.K):
                off, s = self._offsets[k], self._sizes[k]
                W[:, off : off + s] = tbl[self._widx[:, k], :s]
            return W
        V_all = np.concatenate(
            [np.asarray(self._V_pre), np.asarray(self._V_merged)], axis=0
        )
        t_inst = self._t_inst[self._last_phase]
        W = np.zeros((self.A, self.N), np.float32)
        for k in range(self.K):
            off, s = self._offsets[k], self._sizes[k]
            W[:, off : off + s] = V_all[t_inst[:, k], :s]
        return W
