"""Segmented-gossip baseline (Hu et al., arXiv:1908.07782) — the related-work
comparison in paper §4.

Every agent keeps a full local model. Each round: local SGD, then pull each
*segment* (partition) from ``fanout`` random peers and average. Unlike IPLS
there is no responsibility/ownership: every agent stores the whole model and
per-segment traffic grows with the fanout. Used by the scalability benchmark
to reproduce the paper's traffic comparison.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.partition import PartitionSpec, flatten_params
from repro.fl.local_trainer import LocalTrainer
from repro.models import mlp_mnist


def run_gossip(
    shards: List[Tuple[np.ndarray, np.ndarray]],
    x_test: np.ndarray,
    y_test: np.ndarray,
    rounds: int = 40,
    fanout: int = 2,
    num_partitions: int = 10,
    lr: float = 0.1,
    local_iters: int = 10,
    batch_size: int = 128,
    seed: int = 0,
) -> List[dict]:
    rng = np.random.default_rng(seed)
    n = len(shards)
    w0, _ = flatten_params(mlp_mnist.init_params(seed))
    spec = PartitionSpec.even(w0.size, num_partitions)
    offsets = spec.offsets()
    models = [w0.copy() for _ in range(n)]
    trainers = [
        LocalTrainer(a, x, y, lr, local_iters, batch_size, seed)
        for a, (x, y) in enumerate(shards)
    ]
    history = []
    total_bytes = 0
    for rnd in range(rounds):
        # local training
        for a in range(n):
            delta = trainers[a].train_delta(models[a].copy())
            models[a] = models[a] - delta
        # segmented gossip pull: per segment, average over fanout random peers
        new_models = []
        for a in range(n):
            acc = models[a].copy()
            for k in range(spec.num_partitions):
                lo, hi = offsets[k], offsets[k] + spec.sizes[k]
                peers = rng.choice([p for p in range(n) if p != a], size=min(fanout, n - 1), replace=False)
                seg = np.mean([models[p][lo:hi] for p in peers] + [models[a][lo:hi]], axis=0)
                acc[lo:hi] = seg
                # each peer ships its own segment copy; width from the payload
                total_bytes += int(models[a][lo:hi].nbytes * len(peers))
            new_models.append(acc)
        models = new_models
        accs = np.array([trainers[0].evaluate(m, x_test, y_test) for m in models])
        history.append(
            {
                "round": rnd,
                "acc_mean": float(accs.mean()),
                "acc_std": float(accs.std()),
                "acc_max": float(accs.max()),
                "bytes_total": total_bytes,
            }
        )
    return history
