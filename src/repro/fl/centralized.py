"""Centralized FL (FedAvg) baseline — the paper's comparison target (Fig 2).

A server holds W; every round each agent computes its local delta from the
same W; the server applies the mean delta. Identical local-trainer settings
to the IPLS simulation so the comparison isolates decentralisation itself.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.partition import flatten_params
from repro.fl.local_trainer import LocalTrainer
from repro.models import mlp_mnist


def run_centralized(
    shards: List[Tuple[np.ndarray, np.ndarray]],
    x_test: np.ndarray,
    y_test: np.ndarray,
    rounds: int = 40,
    lr: float = 0.1,
    local_iters: int = 10,
    batch_size: int = 128,
    seed: int = 0,
) -> List[dict]:
    w, _layout = flatten_params(mlp_mnist.init_params(seed))
    trainers = [
        LocalTrainer(a, x, y, lr, local_iters, batch_size, seed)
        for a, (x, y) in enumerate(shards)
    ]
    history = []
    for rnd in range(rounds):
        deltas = np.stack([t.train_delta(w.copy()) for t in trainers])
        w = w - deltas.mean(axis=0)
        acc = trainers[0].evaluate(w, x_test, y_test)
        history.append(
            {
                "round": rnd,
                "acc_mean": float(acc),
                "acc_std": 0.0,
                "acc_max": float(acc),
                # server traffic: every agent uploads + downloads the full model
                "bytes_total": int((rnd + 1) * 2 * len(shards) * w.nbytes),
            }
        )
    return history
