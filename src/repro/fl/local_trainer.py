"""Per-agent local optimisation (the paper's M.fit(d_i, SGD) line).

Wraps the MNIST MLP trainer in the flatten/unflatten plumbing that the IPLS
partition plane works over: the trainer takes and returns FLAT weight vectors.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import numpy as np

from repro.core.partition import flatten_params, unflatten_params
from repro.models import mlp_mnist


@dataclasses.dataclass
class LocalTrainer:
    agent_id: int
    x: np.ndarray
    y: np.ndarray
    lr: float = 0.1
    local_iters: int = 10
    batch_size: int = 128
    seed: int = 0

    def __post_init__(self):
        self._layout = None
        self._rng = np.random.default_rng(self.seed + 1000 * (self.agent_id + 1))

    def layout(self):
        if self._layout is None:
            _, self._layout = flatten_params(mlp_mnist.init_params(0))
        return self._layout

    def draw_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Advance this agent's private RNG stream by one round's batch
        selection. The single source of truth for the per-round data order —
        the vectorized engine draws through this same method, which is what
        keeps the two engines' SGD inputs identical."""
        bs = min(self.batch_size, len(self.x))
        sel = self._rng.choice(len(self.x), size=bs, replace=False)
        return self.x[sel], self.y[sel]

    def train_delta(self, w_flat: np.ndarray) -> np.ndarray:
        """Run local SGD from w_flat; return delta = w_before - w_after
        (the paper's convention: holders apply w <- w - eps*delta)."""
        params = unflatten_params(w_flat.astype(np.float32), self.layout())
        xb, yb = self.draw_batch()
        new_params = mlp_mnist.sgd_steps(
            jax.tree.map(np.asarray, params),
            xb,
            yb,
            self.lr,
            self.local_iters,
        )
        new_flat, _ = flatten_params(jax.tree.map(np.asarray, new_params))
        return w_flat - new_flat

    def evaluate(self, w_flat: np.ndarray, x: np.ndarray, y: np.ndarray) -> float:
        params = unflatten_params(w_flat.astype(np.float32), self.layout())
        return float(mlp_mnist.evaluate(jax.tree.map(np.asarray, params), x, y))
