"""Round-structured IPLS simulation: the paper's experiments, end to end.

Wires together: SimIPFS substrate (loss/delay), PartitionTable (pi/rho),
IPLSAgent middleware (Init/UpdateModel/LoadModel/Terminate), LocalTrainer
(local SGD on the agent's private shard), churn schedules, and evaluation.

One simulated round =
  train -> UpdateModel -> tick -> collect -> aggregate -> replies/replica
  sync -> tick -> receive -> (evaluate)
which matches the paper's asynchronous round structure: messages delayed past
a tick are picked up in a later round; lost messages simply never arrive and
the eps-weighting absorbs the shrunken contributor count r.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.api import (
    FETCH_TOPIC,
    IPLSAgent,
    REPLICA_TOPIC,
    REPLY_TOPIC,
    UPDATE_TOPIC,
    reset_registry,
)
from repro.core.partition import PartitionSpec, PartitionTable, flatten_params
from repro.core.wire import make_wire
from repro.fl.local_trainer import LocalTrainer
from repro.models import mlp_mnist
from repro.p2p.ipfs_sim import SimIPFS
from repro.p2p.network import PERFECT, NetworkConditions
from repro.telemetry import NULL_TIMER, MetricsRecorder, TraceWriter
from repro.telemetry.device import host_normsq

# the simulation ticks the substrate 4 times per training round (after the
# fetch requests, the fetch replies, the UpdateModel sends, and the
# reply/replica sends); NetworkConditions delays are in TICK units
TICKS_PER_ROUND = 4

# message channels of the keyed fate stream (see MessageFates)
CH_FETCH, CH_FETCH_REPLY, CH_UPDATE, CH_UPDATE_REPLY, CH_REPLICA, CH_MEMBER = range(6)


class MessageFates:
    """Per-message loss/delay fates keyed by message coordinates.

    Every data-plane message of a round has canonical integer coordinates:
    (channel, round, agent, partition[, peer]). Its fate (delivered?, delay
    in ticks) is a pure hash of those coordinates
    (``NetworkConditions.sample_stream``), NOT a position in a shared
    sequential rng stream. That makes the stream order-free: the scalar
    engine looks fates up one message at a time as its pubsub sends them,
    while the vectorized engine pre-draws the whole round as (A, K) mask /
    delay tensors — both read identical values, which is what makes
    scalar<->vectorized equivalence under LOSSY conditions testable
    round-by-round (weights to float tolerance, traffic counters exactly).
    """

    def __init__(self, conditions: NetworkConditions, seed: int):
        self.conditions = conditions
        self.seed = seed

    def draw(self, channel: int, rnd, agent, part, peer=0):
        """Vectorized fate lookup; arguments broadcast together. Returns
        (delivered bool array, delay-in-ticks int array)."""
        return self.conditions.sample_stream(self.seed, channel, rnd, agent, part, peer)

    def draw_one(self, channel: int, rnd: int, agent: int, part: int, peer: int = 0):
        delivered, delay = self.draw(channel, rnd, agent, part, peer)
        return bool(delivered), int(delay)

    def draw_window(self, channel: int, rounds, agent, part, peer=0):
        """Windowed batch draw: fates for a whole window of rounds at once,
        returned as ``(W, *broadcast(agent, part, peer))`` tensors. Row ``w``
        equals ``draw(channel, rounds[w], agent, part, peer)`` exactly (the
        stream is a pure hash of the coordinates), so the scan engine can
        materialize every per-round mask/delay tensor of a `lax.scan` window
        up front without perturbing the scalar engine's draws."""
        return self.conditions.sample_stream_window(
            self.seed, channel, rounds, agent, part, peer
        )

    def pubsub_fate(
        self, topic: str, sender: int, recipient: int, payload: Any, counter: int
    ) -> Tuple[bool, int]:
        """Adapter installed as ``PubSub.fate_source``: map a concrete
        pubsub message onto its keyed draw. The tick counter identifies the
        round and the phase within it (REPLY messages at phase 1 are fetch
        replies, at phase 3 UpdateModel replies)."""
        rnd, phase = divmod(counter, TICKS_PER_ROUND)
        if topic == UPDATE_TOPIC:
            return self.draw_one(CH_UPDATE, rnd, sender, payload[0])
        if topic == FETCH_TOPIC:
            return self.draw_one(CH_FETCH, rnd, sender, payload[0])
        if topic == REPLY_TOPIC:
            ch = CH_FETCH_REPLY if phase == 1 else CH_UPDATE_REPLY
            # keyed by the REQUESTER (so the requester-side mask tensors of
            # the vectorized engine line up directly) plus the serving
            # holder, so replies racing from different holders draw
            # independent fates. (Two replies from the SAME holder for the
            # same (requester, partition, round) — e.g. a delayed and an
            # on-time delta both landing on a rho=1 holder — share one fate;
            # they carry identical payloads, so only accounting correlates.)
            return self.draw_one(ch, rnd, recipient, payload[0], sender)
        if topic.startswith(REPLICA_TOPIC):
            return self.draw_one(CH_REPLICA, rnd, sender, payload[0], recipient)
        # membership topics: keyed by the pair plus the partition the event
        # concerns, so a multi-partition join/handoff burst draws an
        # independent fate per partition rather than all-or-nothing
        part = 0
        if isinstance(payload, tuple):
            if payload[0] == "join" and len(payload) >= 3:
                part = int(payload[2])
            elif payload[0] == "handoff" and len(payload) >= 2:
                part = int(payload[1])
        return self.draw_one(CH_MEMBER, rnd, sender, part, recipient)


@dataclasses.dataclass
class SimConfig:
    num_agents: int = 10
    num_partitions: int = 10
    pi: int = 2
    rho: int = 1
    alpha: float = 0.5
    rounds: int = 40
    lr: float = 0.1
    local_iters: int = 10
    batch_size: int = 128
    seed: int = 0
    eval_agents: int = 0  # evaluate at most this many agents per round (0 = all)
    conditions: NetworkConditions = PERFECT
    # churn: map round -> list of (agent_id, action) events applied at the
    # START of that round, action in "offline"|"online"|"leave"|"crash"|"join".
    # Same-round events apply in a DETERMINISTIC order regardless of list
    # order: leave/crash first, then join, then offline/online (stable within
    # each class). So {r: [(3, "join"), (3, "crash")]} always crashes the
    # pre-existing agent 3 and then admits a fresh one — it never resurrects
    # crashed state — and both engines apply the identical order.
    churn: Optional[Dict[int, List[Tuple[int, str]]]] = None
    memory: bool = True  # False = 'memoryless training' (paper Fig 3b)
    # round engine: "scalar" (per-agent loops) or "vectorized" (whole-round
    # batched device calls; any NetworkConditions, churn included — event
    # rounds replay on the embedded scalar oracle and the dense planes are
    # re-snapshotted at the boundary; see fl/vectorized.py and docs/ENGINE.md)
    engine: str = "scalar"
    # multi-round fusion (vectorized engine only): 0 = one device call per
    # round; W >= 1 = run windows of W rounds as ONE lax.scan-driven device
    # call each, with batches / fate tensors / routing tables pre-drawn for
    # the whole window (see docs/ENGINE.md "Multi-round fused scan")
    scan_rounds: int = 0
    # scanned-mode evaluation cadence: evaluate every `eval_cadence`-th round
    # (plus the final round); skipped rounds reuse the last computed accuracy
    # in the history. 1 (default) evaluates every round, so accuracy traces
    # are identical to the unscanned engines.
    eval_cadence: int = 1
    # data shard for agents added by a "join" churn action: a callable
    # agent_id -> (x, y). None = round-robin over the initial shards.
    join_shard: Optional[Callable[[int], Tuple[np.ndarray, np.ndarray]]] = None
    # wire format for delta / value transfers: "f32" (raw) or "int8"
    # (block-int8 + per-block scales + error feedback on the delta channel —
    # ~4x fewer bytes_total; see core/wire.py and docs/ENGINE.md)
    wire_dtype: str = "f32"
    # observability (repro.telemetry, docs/TELEMETRY.md): telemetry=True
    # attaches a MetricsRecorder emitting one schema-ordered row per round —
    # byte-for-byte identical across engines — plus per-phase wall timers;
    # trace=True additionally records a Chrome trace-event timeline
    # (protocol sends/deliveries/drops on simulated ticks + host phase
    # spans). Both default off: the disabled path adds no device outputs
    # (unchanged jaxprs) and no per-message work.
    telemetry: bool = False
    trace: bool = False


def eval_subset(live: List[int], eval_agents: int) -> List[int]:
    """Deterministic stride-spread of at most ``eval_agents`` agents over the
    live set (0 = all). Shared by both engines so they evaluate the same
    agents."""
    if eval_agents and len(live) > eval_agents:
        stride = max(len(live) // eval_agents, 1)
        live = live[::stride][:eval_agents]
    return live


def make_simulation(cfg: SimConfig, shards, x_test, y_test):
    """Engine factory: returns the simulation object for ``cfg.engine``.

    Both engines expose ``run() -> List[dict]`` / ``run_round`` / ``history``
    and produce equivalent results under PERFECT *and* LOSSY conditions
    (property-tested in tests/test_vectorized.py — weights to float
    tolerance, traffic counters exactly); the vectorized engine batches
    each round into a handful of device calls and is the one to use at
    scale. Churn schedules run on both engines: the vectorized engine
    replays membership-event rounds through the scalar oracle and
    re-snapshots its dense planes at the event boundaries (docs/ENGINE.md
    "Churn re-snapshot").
    """
    if cfg.engine == "vectorized":
        from repro.fl.vectorized import VectorizedIPLSSimulation

        return VectorizedIPLSSimulation(cfg, shards, x_test, y_test)
    if cfg.engine != "scalar":
        raise ValueError(f"unknown engine {cfg.engine!r}")
    return IPLSSimulation(cfg, shards, x_test, y_test)


class IPLSSimulation:
    def __init__(self, cfg: SimConfig, shards, x_test, y_test):
        self.cfg = cfg
        self.x_test, self.y_test = x_test, y_test
        self._shards = shards
        reset_registry()
        self.net = SimIPFS(cfg.conditions, cfg.seed)
        # imperfect connectivity: install the keyed fate stream so every
        # message's loss/delay is a pure function of its coordinates (shared
        # with the vectorized engine's pre-drawn mask tensors)
        self.fates: Optional[MessageFates] = None
        if cfg.conditions.loss_prob > 0 or cfg.conditions.delay_prob > 0:
            self.fates = MessageFates(cfg.conditions, cfg.seed)
            self.net.pubsub.fate_source = self.fates.pubsub_fate
        w0_params = mlp_mnist.init_params(cfg.seed)
        self.w0, self.layout = flatten_params(w0_params)
        self.spec = PartitionSpec.even(self.w0.size, cfg.num_partitions)
        self.table = PartitionTable(cfg.num_partitions, cfg.pi, cfg.rho)
        self.wire = make_wire(cfg.wire_dtype)
        self.agents: Dict[int, IPLSAgent] = {}
        self.trainers: Dict[int, LocalTrainer] = {}
        for a in range(cfg.num_agents):
            agent = IPLSAgent(a, self.net, self.table, self.spec, cfg.alpha, wire=self.wire)
            agent.init(self.w0 if a == 0 else None)
            self.agents[a] = agent
            x, y = shards[a]
            self.trainers[a] = LocalTrainer(
                a, x, y, cfg.lr, cfg.local_iters, cfg.batch_size, cfg.seed
            )
        # joiner shard bookkeeping (see _next_free_shard): shard index backing
        # each trainer created from self._shards, and the round-robin cursor
        self._trainer_shard: Dict[int, int] = {a: a for a in range(cfg.num_agents)}
        self._join_rr = 0
        self.history: List[dict] = []
        # observability: attached AFTER init so the join/bootstrap traffic is
        # excluded from the per-round streams in both engines identically
        # (it still shows in the cumulative *_total counters via the pubsub)
        self.recorder: Optional[MetricsRecorder] = None
        self._pt = NULL_TIMER
        if cfg.telemetry:
            self.recorder = MetricsRecorder(
                ticks_per_round=TICKS_PER_ROUND,
                max_delay_ticks=cfg.conditions.max_delay_rounds,
                trace=TraceWriter() if cfg.trace else None,
            )
            self._pt = self.recorder.timer
            self.net.pubsub.telemetry = self.recorder
            # padded instance width shared with the vectorized value planes
            # (int8: whole quantization blocks, mirroring fl/vectorized.py)
            from repro.core.wire import BLOCK as _WB

            s_max = int(max(self.spec.sizes))
            self._tel_S = (
                -(-s_max // _WB) * _WB if cfg.wire_dtype == "int8" else s_max
            )

    # -- churn handling -----------------------------------------------------
    # Same-round events are applied in a deterministic class order (see the
    # SimConfig.churn comment): departures first, then joins, then
    # offline/online toggles; the sort is stable so same-class events keep
    # their schedule order. The vectorized engine replays event rounds
    # through this same method, so both engines agree by construction.
    _CHURN_ORDER = {"leave": 0, "crash": 0, "join": 1, "offline": 2, "online": 2}

    def _apply_churn(self, rnd: int) -> None:
        if not self.cfg.churn:
            return
        events = sorted(
            self.cfg.churn.get(rnd, []),
            key=lambda ev: self._CHURN_ORDER.get(ev[1], 3),
        )
        for agent_id, action in events:
            if action == "offline":
                self.net.pubsub.set_offline(agent_id, True)
            elif action == "online":
                self.net.pubsub.set_offline(agent_id, False)
                if not self.cfg.memory and agent_id in self.agents:
                    # memoryless rejoin: lose the cached global parts
                    self.agents[agent_id].cache.clear()
            elif action == "leave":
                if agent_id in self.agents:
                    self.agents[agent_id].terminate()
            elif action == "crash":
                if agent_id in self.agents:
                    self.agents[agent_id].crash()
            elif action == "join":
                agent = IPLSAgent(
                    agent_id, self.net, self.table, self.spec, self.cfg.alpha, wire=self.wire
                )
                agent.init()
                self.agents[agent_id] = agent
                # a joiner without a trainer never contributes a delta
                # (run_round skips training for agents not in self.trainers):
                # give it a data shard so it participates
                if agent_id not in self.trainers:
                    if self.cfg.join_shard is not None:
                        x, y = self.cfg.join_shard(agent_id)
                    else:
                        shard_idx = self._next_free_shard(agent_id)
                        self._trainer_shard[agent_id] = shard_idx
                        x, y = self._shards[shard_idx]
                    self.trainers[agent_id] = LocalTrainer(
                        agent_id, x, y, self.cfg.lr, self.cfg.local_iters,
                        self.cfg.batch_size, self.cfg.seed,
                    )

    def _next_free_shard(self, agent_id: int) -> int:
        """Pick a data shard for a joiner: round-robin over shards not held
        by any live agent's trainer, so a joiner whose id aliases an active
        agent's shard index does not double-count that data in the average.
        Falls back to ``agent_id % len(shards)`` only when every shard is
        taken."""
        used = {
            self._trainer_shard[a]
            for a, ag in self.agents.items()
            if ag.live and a != agent_id and a in self._trainer_shard
        }
        n = len(self._shards)
        free = [i for i in range(n) if i not in used]
        if not free:
            return agent_id % n
        for _ in range(n):
            idx = self._join_rr % n
            self._join_rr += 1
            if idx in free:
                return idx
        return free[0]

    def _live_online(self) -> List[int]:
        return [
            a
            for a, ag in self.agents.items()
            if ag.live and not self.net.pubsub.is_offline(a)
        ]

    # -- one round ------------------------------------------------------------
    def run_round(self, rnd: int) -> dict:
        self._apply_churn(rnd)
        active = self._live_online()
        rec = self.recorder

        # 0. collect missing global parameters (paper: 'each agent initially
        # contacts enough agents to collect the global parameters'; also how
        # rejoining agents warm back up)
        with self._pt.phase("fetch"):
            for a in active:
                self.agents[a].request_missing(rnd)
            self.net.tick()
            for a in active:
                self.agents[a].serve_fetches()
            self.net.tick()
            for a in active:
                self.agents[a].receive_replies()

        # 1. local training + UpdateModel
        deltas: List[np.ndarray] = []
        with self._pt.phase("train"):
            for a in active:
                if a not in self.trainers:
                    continue
                w = self.agents[a].load_model()
                delta = self.trainers[a].train_delta(w)
                if rec is not None:
                    deltas.append(delta)
                self.agents[a].update_model(delta, rnd)
            self.net.tick()

        # 2. holders aggregate + reply; replicas sync
        with self._pt.phase("aggregate"):
            for a in active:
                self.agents[a].collect()
            # contributor counts: captured between drain and aggregate, when
            # every instance's pending buffer holds this round's full r
            instances = contrib = None
            if rec is not None:
                instances = self._tel_instances()
                contrib = [
                    st.pending_n if st is not None else 0
                    for st in self._tel_states(instances)
                ]
            for a in active:
                self.agents[a].aggregate()
            for a in active:
                self.agents[a].serve_replies()
                self.agents[a].sync_replicas(rnd)
            self.net.tick()
            for a in active:
                self.agents[a].receive_replies()
                self.agents[a].merge_replicas()

        # 3. evaluate the assembled model
        with self._pt.phase("eval"):
            accs = self._eval_accs()
        metrics = self._acc_metrics(accs)
        metrics["round"] = rnd
        metrics["active"] = len(active)
        metrics["bytes_total"] = self.net.pubsub.total_bytes()
        self.history.append(metrics)
        if rec is not None:
            self._tel_finish(rnd, len(active), deltas, instances, contrib, accs)
        return metrics

    def evaluate(self) -> dict:
        return self._acc_metrics(self._eval_accs())

    def _eval_accs(self) -> np.ndarray:
        accs = []
        any_trainer = next(iter(self.trainers.values()))
        live = eval_subset(
            [a for a, ag in self.agents.items() if ag.live], self.cfg.eval_agents
        )
        for a in live:
            w = self.agents[a].load_model()
            accs.append(any_trainer.evaluate(w, self.x_test, self.y_test))
        return np.array(accs) if accs else np.array([0.0])

    @staticmethod
    def _acc_metrics(accs: np.ndarray) -> dict:
        return {
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "acc_max": float(accs.max()),
        }

    # -- telemetry emission (one finish_round per round; see repro.telemetry)
    def _tel_instances(self) -> List[Tuple[int, int]]:
        """(partition, holder) instance list, k-major in holder order — the
        row order of the vectorized engine's value tables."""
        return [
            (k, h)
            for k in range(self.cfg.num_partitions)
            for h in self.table.holders_of(k)
        ]

    def _tel_states(self, instances):
        for k, h in instances:
            ag = self.agents.get(h)
            yield ag.owned.get(k) if ag is not None else None

    def _tel_finish(self, rnd, n_active, deltas, instances, contrib, accs):
        S = self._tel_S
        V = np.zeros((len(instances), S), np.float32)
        eps = []
        for i, st in enumerate(self._tel_states(instances)):
            if st is not None:
                V[i, : st.value.size] = st.value
                eps.append(st.eps)
            else:
                eps.append(1.0)
        if deltas:
            dn = host_normsq(np.stack(deltas))
        else:
            dn = 0.0
        self.recorder.finish_round(
            round=rnd,
            active=n_active,
            contrib=contrib,
            eps=eps,
            delta_normsq=dn,
            value_normsq=host_normsq(V),
            accs=accs,
            bytes_total=self.net.pubsub.total_bytes(),
            msgs_total=self.net.pubsub.messages_sent,
            drops_total=self.net.pubsub.messages_dropped,
        )

    def run(self) -> List[dict]:
        for rnd in range(self.cfg.rounds):
            self.run_round(rnd)
        return self.history
