"""Round-structured IPLS simulation: the paper's experiments, end to end.

Wires together: SimIPFS substrate (loss/delay), PartitionTable (pi/rho),
IPLSAgent middleware (Init/UpdateModel/LoadModel/Terminate), LocalTrainer
(local SGD on the agent's private shard), churn schedules, and evaluation.

One simulated round =
  train -> UpdateModel -> tick -> collect -> aggregate -> replies/replica
  sync -> tick -> receive -> (evaluate)
which matches the paper's asynchronous round structure: messages delayed past
a tick are picked up in a later round; lost messages simply never arrive and
the eps-weighting absorbs the shrunken contributor count r.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.api import IPLSAgent, reset_registry
from repro.core.partition import PartitionSpec, PartitionTable
from repro.fl.local_trainer import LocalTrainer
from repro.models import mlp_mnist
from repro.core.partition import flatten_params
from repro.p2p.ipfs_sim import SimIPFS
from repro.p2p.network import NetworkConditions, PERFECT


@dataclasses.dataclass
class SimConfig:
    num_agents: int = 10
    num_partitions: int = 10
    pi: int = 2
    rho: int = 1
    alpha: float = 0.5
    rounds: int = 40
    lr: float = 0.1
    local_iters: int = 10
    batch_size: int = 128
    seed: int = 0
    eval_agents: int = 0  # evaluate at most this many agents per round (0 = all)
    conditions: NetworkConditions = PERFECT
    # churn: map round -> list of (agent_id, "offline"|"online"|"leave"|"crash"|"join")
    churn: Optional[Dict[int, List[Tuple[int, str]]]] = None
    memory: bool = True  # False = 'memoryless training' (paper Fig 3b)
    # round engine: "scalar" (per-agent loops; handles loss/delay/churn) or
    # "vectorized" (whole-round batched device calls; PERFECT + no churn
    # only — see fl/vectorized.py and docs/ENGINE.md)
    engine: str = "scalar"


def eval_subset(live: List[int], eval_agents: int) -> List[int]:
    """Deterministic stride-spread of at most ``eval_agents`` agents over the
    live set (0 = all). Shared by both engines so they evaluate the same
    agents."""
    if eval_agents and len(live) > eval_agents:
        stride = max(len(live) // eval_agents, 1)
        live = live[::stride][:eval_agents]
    return live


def make_simulation(cfg: SimConfig, shards, x_test, y_test):
    """Engine factory: returns the simulation object for ``cfg.engine``.

    Both engines expose ``run() -> List[dict]`` / ``run_round`` / ``history``
    and produce equivalent results under PERFECT conditions (property-tested
    in tests/test_vectorized.py); the vectorized engine batches each round
    into three device calls and is the one to use at scale.
    """
    if cfg.engine == "vectorized":
        from repro.fl.vectorized import VectorizedIPLSSimulation

        return VectorizedIPLSSimulation(cfg, shards, x_test, y_test)
    if cfg.engine != "scalar":
        raise ValueError(f"unknown engine {cfg.engine!r}")
    return IPLSSimulation(cfg, shards, x_test, y_test)


class IPLSSimulation:
    def __init__(self, cfg: SimConfig, shards, x_test, y_test):
        self.cfg = cfg
        self.x_test, self.y_test = x_test, y_test
        reset_registry()
        self.net = SimIPFS(cfg.conditions, cfg.seed)
        w0_params = mlp_mnist.init_params(cfg.seed)
        self.w0, self.layout = flatten_params(w0_params)
        self.spec = PartitionSpec.even(self.w0.size, cfg.num_partitions)
        self.table = PartitionTable(cfg.num_partitions, cfg.pi, cfg.rho)
        self.agents: Dict[int, IPLSAgent] = {}
        self.trainers: Dict[int, LocalTrainer] = {}
        for a in range(cfg.num_agents):
            agent = IPLSAgent(a, self.net, self.table, self.spec, cfg.alpha)
            agent.init(self.w0 if a == 0 else None)
            self.agents[a] = agent
            x, y = shards[a]
            self.trainers[a] = LocalTrainer(
                a, x, y, cfg.lr, cfg.local_iters, cfg.batch_size, cfg.seed
            )
        self.history: List[dict] = []

    # -- churn handling -----------------------------------------------------
    def _apply_churn(self, rnd: int) -> None:
        if not self.cfg.churn:
            return
        for agent_id, action in self.cfg.churn.get(rnd, []):
            if action == "offline":
                self.net.pubsub.set_offline(agent_id, True)
            elif action == "online":
                self.net.pubsub.set_offline(agent_id, False)
                if not self.cfg.memory and agent_id in self.agents:
                    # memoryless rejoin: lose the cached global parts
                    self.agents[agent_id].cache.clear()
            elif action == "leave":
                if agent_id in self.agents:
                    self.agents[agent_id].terminate()
            elif action == "crash":
                if agent_id in self.agents:
                    self.agents[agent_id].crash()
            elif action == "join":
                agent = IPLSAgent(agent_id, self.net, self.table, self.spec, self.cfg.alpha)
                agent.init()
                self.agents[agent_id] = agent

    def _live_online(self) -> List[int]:
        return [
            a
            for a, ag in self.agents.items()
            if ag.live and not self.net.pubsub.is_offline(a)
        ]

    # -- one round ------------------------------------------------------------
    def run_round(self, rnd: int) -> dict:
        self._apply_churn(rnd)
        active = self._live_online()

        # 0. collect missing global parameters (paper: 'each agent initially
        # contacts enough agents to collect the global parameters'; also how
        # rejoining agents warm back up)
        for a in active:
            self.agents[a].request_missing(rnd)
        self.net.tick()
        for a in active:
            self.agents[a].serve_fetches()
        self.net.tick()
        for a in active:
            self.agents[a].receive_replies()

        # 1. local training + UpdateModel
        for a in active:
            if a not in self.trainers:
                continue
            w = self.agents[a].load_model()
            delta = self.trainers[a].train_delta(w)
            self.agents[a].update_model(delta, rnd)
        self.net.tick()

        # 2. holders aggregate + reply; replicas sync
        for a in active:
            self.agents[a].collect()
        for a in active:
            self.agents[a].aggregate()
        for a in active:
            self.agents[a].serve_replies()
            self.agents[a].sync_replicas(rnd)
        self.net.tick()
        for a in active:
            self.agents[a].receive_replies()
            self.agents[a].merge_replicas()

        # 3. evaluate the assembled model
        metrics = self.evaluate()
        metrics["round"] = rnd
        metrics["active"] = len(active)
        metrics["bytes_total"] = self.net.pubsub.total_bytes()
        self.history.append(metrics)
        return metrics

    def evaluate(self) -> dict:
        accs = []
        any_trainer = next(iter(self.trainers.values()))
        live = eval_subset(
            [a for a, ag in self.agents.items() if ag.live], self.cfg.eval_agents
        )
        for a in live:
            w = self.agents[a].load_model()
            accs.append(any_trainer.evaluate(w, self.x_test, self.y_test))
        accs = np.array(accs) if accs else np.array([0.0])
        return {
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "acc_max": float(accs.max()),
        }

    def run(self) -> List[dict]:
        for rnd in range(self.cfg.rounds):
            self.run_round(rnd)
        return self.history
