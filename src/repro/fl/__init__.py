from repro.fl.local_trainer import LocalTrainer
from repro.fl.centralized import run_centralized
from repro.fl.rounds import IPLSSimulation, SimConfig
from repro.fl.gossip import run_gossip

__all__ = [
    "LocalTrainer",
    "run_centralized",
    "IPLSSimulation",
    "SimConfig",
    "run_gossip",
]
