from repro.fl.local_trainer import LocalTrainer
from repro.fl.centralized import run_centralized
from repro.fl.rounds import IPLSSimulation, SimConfig, make_simulation
from repro.fl.gossip import run_gossip

__all__ = [
    "LocalTrainer",
    "run_centralized",
    "IPLSSimulation",
    "SimConfig",
    "make_simulation",
    "run_gossip",
]
