"""In-process IPFS substitute: content-addressed store + pub/sub topics.

Offline container => no real IPFS daemon. This module provides the two IPFS
facilities IPLS uses (paper §2.2):

  * a content-addressed blob store (add -> CID, cat CID -> bytes), used by
    Terminate() to hand off partition values;
  * pub/sub topics, used for initialisation broadcast, membership events and
    partition-update exchange.

Messages traverse a ``NetworkConditions`` model (loss/delay in *rounds*,
matching the paper's round-structured asynchrony). Delivery is pulled by the
simulation driver calling ``tick()`` once per training round.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.p2p.network import NetworkConditions, PERFECT


class ContentStore:
    """Content-addressed storage: CID = sha256 of the payload bytes."""

    def __init__(self) -> None:
        self._blobs: Dict[str, bytes] = {}

    def add(self, data: bytes) -> str:
        cid = hashlib.sha256(data).hexdigest()
        self._blobs[cid] = data
        return cid

    def cat(self, cid: str) -> bytes:
        if cid not in self._blobs:
            raise KeyError(f"unknown CID {cid[:12]}…")
        return self._blobs[cid]

    def has(self, cid: str) -> bool:
        return cid in self._blobs

    def __len__(self) -> int:
        return len(self._blobs)


@dataclasses.dataclass
class Message:
    topic: str
    sender: int
    payload: Any
    sent_round: int
    deliver_round: int
    nbytes: int
    # every in-flight message is addressed to exactly one recipient: directed
    # sends trivially, and published messages because loss/delay are sampled
    # PER SUBSCRIBER at publish time (fanning out again at delivery would
    # deliver each value (subs-1)^2 times — observed as a duplicate-weighted
    # replica merge for rho >= 3).
    recipient: int = -1


class PubSub:
    """Topic-based pub/sub with per-message loss/delay and traffic metering."""

    def __init__(self, conditions: NetworkConditions = PERFECT, seed: int = 0):
        self.conditions = conditions
        self.rng = np.random.default_rng(seed)
        self._subs: Dict[str, List[int]] = defaultdict(list)
        self._inflight: List[Message] = []
        self._inbox: Dict[int, List[Message]] = defaultdict(list)
        self.round = 0
        # traffic accounting: bytes sent/received per agent (for scalability bench)
        self.bytes_sent: Dict[int, int] = defaultdict(int)
        self.bytes_recv: Dict[int, int] = defaultdict(int)
        self.messages_sent = 0
        self.messages_dropped = 0
        self._offline: set[int] = set()
        # optional keyed fate source: (topic, sender, recipient, payload,
        # round) -> (delivered, delay). When set, per-message loss/delay is
        # a pure function of the message's coordinates instead of the shared
        # sequential rng — the round engines install this so the scalar and
        # vectorized data planes draw identical fates (see
        # fl/rounds.MessageFates). When None, the legacy sequential
        # Generator stream is used.
        self.fate_source: Optional[
            Callable[[str, int, int, Any, int], Tuple[bool, int]]
        ] = None
        # optional MetricsRecorder tap (repro.telemetry). None = every tap
        # site is a single falsy check; counters above stay authoritative.
        self.telemetry = None

    def _fate(self, topic: str, sender: int, recipient: int, payload: Any) -> Tuple[bool, int]:
        if self.fate_source is not None:
            return self.fate_source(topic, sender, recipient, payload, self.round)
        return self.conditions.sample(self.rng)

    # -- membership of the transport --------------------------------------
    def subscribe(self, topic: str, agent: int) -> None:
        if agent not in self._subs[topic]:
            self._subs[topic].append(agent)

    def unsubscribe(self, topic: str, agent: int) -> None:
        if agent in self._subs[topic]:
            self._subs[topic].remove(agent)

    def set_offline(self, agent: int, offline: bool) -> None:
        """Paper: agents 'may get disconnected ... for a short while'."""
        if offline:
            self._offline.add(agent)
        else:
            self._offline.discard(agent)

    def is_offline(self, agent: int) -> bool:
        return agent in self._offline

    # -- data plane --------------------------------------------------------
    def publish(self, topic: str, sender: int, payload: Any, nbytes: int) -> None:
        tel = self.telemetry
        if sender in self._offline:
            self.messages_dropped += 1
            if tel is not None:
                tel.on_offline_drop(self.round)
            return
        self.messages_sent += 1
        self.bytes_sent[sender] += nbytes
        if tel is not None:
            tel.on_send(topic, self.round, sender, nbytes)
        for agent in self._subs[topic]:
            if agent == sender:
                continue
            delivered, delay = self._fate(topic, sender, agent, payload)
            if not delivered:
                self.messages_dropped += 1
                if tel is not None:
                    tel.on_fate(topic, self.round, sender, agent, False, delay)
                continue
            if agent in self._offline:
                self.messages_dropped += 1
                if tel is not None:
                    tel.on_offline_drop(self.round)
                continue
            if tel is not None:
                tel.on_fate(topic, self.round, sender, agent, True, delay)
            self._inflight.append(
                Message(
                    topic=topic,
                    sender=sender,
                    payload=payload,
                    sent_round=self.round,
                    deliver_round=self.round + delay,
                    nbytes=nbytes,
                    recipient=agent,
                )
            )

    def send(self, topic: str, sender: int, recipient: int, payload: Any, nbytes: int) -> None:
        """Directed message (UpdateModel request/reply); same loss/delay model."""
        tel = self.telemetry
        if sender in self._offline:
            self.messages_dropped += 1
            if tel is not None:
                tel.on_offline_drop(self.round)
            return
        self.messages_sent += 1
        self.bytes_sent[sender] += nbytes
        if tel is not None:
            tel.on_send(topic, self.round, sender, nbytes)
        delivered, delay = self._fate(topic, sender, recipient, payload)
        if not delivered:
            self.messages_dropped += 1
            if tel is not None:
                tel.on_fate(topic, self.round, sender, recipient, False, delay)
            return
        if recipient in self._offline:
            self.messages_dropped += 1
            if tel is not None:
                tel.on_offline_drop(self.round)
            return
        if tel is not None:
            tel.on_fate(topic, self.round, sender, recipient, True, delay)
        self._inflight.append(
            Message(
                topic=topic,
                sender=sender,
                payload=payload,
                sent_round=self.round,
                deliver_round=self.round + delay,
                nbytes=nbytes,
                recipient=recipient,
            )
        )

    def tick(self) -> None:
        """Advance one round: deliver everything due this round."""
        tel = self.telemetry
        still: List[Message] = []
        for msg in self._inflight:
            if msg.deliver_round > self.round:
                still.append(msg)
                continue
            agent = msg.recipient
            if agent in self._offline:
                self.messages_dropped += 1
                if tel is not None:
                    tel.on_offline_drop(self.round)
                continue
            self._inbox[agent].append(msg)
            self.bytes_recv[agent] += msg.nbytes
            if tel is not None:
                tel.on_delivery(
                    msg.topic, msg.sent_round, self.round, msg.sender, agent,
                    msg.nbytes,
                )
        self._inflight = still
        self.round += 1

    def drain(self, agent: int, topic_prefix: str = "") -> List[Message]:
        box = self._inbox[agent]
        if not topic_prefix:
            out, self._inbox[agent] = box, []
            return out
        # true prefix semantics: substring matching would cross-drain any
        # topic embedding another topic's name mid-string
        out = [m for m in box if m.topic.startswith(topic_prefix)]
        self._inbox[agent] = [m for m in box if not m.topic.startswith(topic_prefix)]
        return out

    def total_bytes(self) -> int:
        return sum(self.bytes_sent.values())


class SimIPFS:
    """The bundle an IPLS agent sees: one shared store + one shared pubsub.

    Mirrors the role of the IPFS daemon each agent runs in the paper; since we
    simulate in-process, all agents share the same substrate object.
    """

    def __init__(self, conditions: NetworkConditions = PERFECT, seed: int = 0):
        self.store = ContentStore()
        self.pubsub = PubSub(conditions, seed)

    def tick(self) -> None:
        self.pubsub.tick()
