from repro.p2p.ipfs_sim import ContentStore, PubSub, SimIPFS
from repro.p2p.network import NetworkConditions, PERFECT, LOSSY

__all__ = [
    "ContentStore",
    "PubSub",
    "SimIPFS",
    "NetworkConditions",
    "PERFECT",
    "LOSSY",
]
