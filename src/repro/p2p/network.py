"""Seeded network-condition models for the IPFS substrate simulation.

The paper evaluates IPLS under mininet with 'perfect connectivity' and
'imperfect connectivity' where 'messages ... are probable to be lost or to be
delivered after the start of the next training iteration'. We model exactly
those two effects per message:

  * loss:   message dropped with prob ``loss_prob``;
  * delay:  message delivered ``d`` rounds late, d ~ Geometric(delay_prob),
            capped at ``max_delay_rounds``.

Determinism: every decision is drawn from a numpy Generator seeded at
construction, so experiments are exactly reproducible.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkConditions:
    loss_prob: float = 0.0
    delay_prob: float = 0.0
    max_delay_rounds: int = 2

    def sample(self, rng: np.random.Generator) -> tuple[bool, int]:
        """Returns (delivered, delay_rounds) for one message."""
        if self.loss_prob > 0 and rng.random() < self.loss_prob:
            return False, 0
        delay = 0
        if self.delay_prob > 0:
            while delay < self.max_delay_rounds and rng.random() < self.delay_prob:
                delay += 1
        return True, delay


PERFECT = NetworkConditions()
# "imperfect connectivity" setting used in the paper-matching experiments
LOSSY = NetworkConditions(loss_prob=0.15, delay_prob=0.25, max_delay_rounds=2)
