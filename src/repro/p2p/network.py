"""Seeded network-condition models for the IPFS substrate simulation.

The paper evaluates IPLS under mininet with 'perfect connectivity' and
'imperfect connectivity' where 'messages ... are probable to be lost or to be
delivered after the start of the next training iteration'. We model exactly
those two effects per message:

  * loss:   message dropped with prob ``loss_prob``;
  * delay:  message delivered ``d`` rounds late, d ~ Geometric(delay_prob),
            capped at ``max_delay_rounds``.

Determinism: every decision is drawn from a numpy Generator seeded at
construction, so experiments are exactly reproducible.

Two sampling modes:

  * ``sample(rng)``        — sequential per-message draws from a shared
    Generator (order-dependent: the stream shifts if any message is added
    or removed earlier in the run);
  * ``sample_stream(...)`` — counter-based draws keyed by integer message
    coordinates (channel, round, sender, partition, peer). Each message's
    fate is a pure hash of its key, so any subset of messages can be drawn
    in any order — scalar per-message lookups and whole-round batched
    tensors read the *same* values. This is what lets the vectorized round
    engine pre-draw a round's loss/delay masks while the scalar oracle
    looks the very same fates up one message at a time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_U64 = np.uint64
_GOLDEN = _U64(0x9E3779B97F4A7C15)
_MIX = _U64(0xD1B54A32D192ED03)
_INV_2_53 = float(2.0**-53)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer on uint64 arrays (wraparound arithmetic)."""
    z = (x + _GOLDEN).astype(_U64)
    z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
    return z ^ (z >> _U64(31))


def hash_uniform(*components) -> np.ndarray:
    """Broadcast integer components to a common shape and hash them into
    float64 uniforms in [0, 1). Pure function of the components."""
    with np.errstate(over="ignore"):  # uint64 wraparound is the point
        arrs = np.broadcast_arrays(*[np.asarray(c, np.uint64) for c in components])
        h = np.zeros(arrs[0].shape, _U64)
        for a in arrs:
            h = _splitmix64(h ^ (a * _MIX))
        return (h >> _U64(11)).astype(np.float64) * _INV_2_53


@dataclasses.dataclass(frozen=True)
class NetworkConditions:
    loss_prob: float = 0.0
    delay_prob: float = 0.0
    max_delay_rounds: int = 2

    def sample(self, rng: np.random.Generator) -> tuple[bool, int]:
        """Returns (delivered, delay_rounds) for one message."""
        if self.loss_prob > 0 and rng.random() < self.loss_prob:
            return False, 0
        delay = 0
        if self.delay_prob > 0:
            while delay < self.max_delay_rounds and rng.random() < self.delay_prob:
                delay += 1
        return True, delay

    def sample_stream(self, seed: int, *key) -> tuple[np.ndarray, np.ndarray]:
        """Batched counter-based fates: ``key`` components are integers or
        integer arrays (broadcast together); returns (delivered, delay)
        arrays of the broadcast shape. The last hash component is a draw
        slot: 0 decides loss, 1..max_delay_rounds decide the capped
        geometric delay, so per-key results match ``sample``'s
        distribution exactly and never depend on draw order."""
        u_loss = hash_uniform(seed, *key, 0)
        delivered = (
            u_loss >= self.loss_prob if self.loss_prob > 0
            else np.ones(u_loss.shape, bool)
        )
        delay = np.zeros(u_loss.shape, np.int64)
        if self.delay_prob > 0:
            for slot in range(1, self.max_delay_rounds + 1):
                u = hash_uniform(seed, *key, slot)
                # capped geometric: delay += 1 while every earlier draw hit
                delay += np.where((u < self.delay_prob) & (delay == slot - 1), 1, 0)
        delay = np.where(delivered, delay, 0)
        return delivered, delay

    def sample_stream_window(
        self, seed: int, channel: int, rounds, *key
    ) -> tuple[np.ndarray, np.ndarray]:
        """Windowed batch draw: materialize a whole window of rounds' fates
        up front as ``(W, *broadcast(key))`` tensors. ``rounds`` is a 1-D
        array of round indices; the remaining key components broadcast as in
        ``sample_stream``. Because fates are pure hashes of their
        coordinates, slicing row ``w`` of the result equals a per-round
        ``sample_stream(seed, channel, rounds[w], *key)`` draw exactly —
        this is what lets the multi-round scan engine pre-draw every fate
        tensor of a ``lax.scan`` window in one hashing pass."""
        rounds = np.asarray(rounds, np.int64)
        if key:
            b = np.broadcast(*[np.asarray(c) for c in key])
            rounds = rounds.reshape(rounds.shape + (1,) * b.ndim)
        return self.sample_stream(seed, channel, rounds, *key)


PERFECT = NetworkConditions()
# "imperfect connectivity" setting used in the paper-matching experiments
LOSSY = NetworkConditions(loss_prob=0.15, delay_prob=0.25, max_delay_rounds=2)
