import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and report memory/cost/roofline terms.

MUST be run as a module entry point (the XLA_FLAGS line above executes before
any jax import): ``PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b``.

For every applicable (arch, shape):
    single-pod mesh (16,16) ("data","model")      -> roofline table entry
    multi-pod mesh (2,16,16) ("pod","data","model") -> proves the pod axis
Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system — the run exits non-zero.
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    from repro.configs import SHAPES, build_model, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step, lower_step
    from repro.roofline.analysis import analyze_compiled, model_flops_for

    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "status": "skipped", "why": why}

    t0 = time.time()
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(len(mesh.device_ids.flat))
    kw = {}
    if shape.kind == "train":
        from repro.core.sharded import IplsStepConfig
        from repro.launch.steps import TRAIN_OVERRIDES
        kw["step_cfg"] = IplsStepConfig(**TRAIN_OVERRIDES.get(arch, {}))
    built = build_step(model, mesh, shape, **kw)
    lowered = lower_step(built)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    report = analyze_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_desc=mesh_desc,
        chips=chips,
        model_flops=model_flops_for(model, shape.kind, shape.seq_len, shape.global_batch),
    )
    row = report.row()
    row.update(
        status="ok",
        multi_pod=multi_pod,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        arg_bytes_per_dev=getattr(mem, "argument_size_in_bytes", None),
        temp_bytes_per_dev=getattr(mem, "temp_size_in_bytes", None),
        output_bytes_per_dev=getattr(mem, "output_size_in_bytes", None),
        collective_bytes=report.collective_bytes,
    )
    if verbose:
        print(f"--- {arch} x {shape_name} x {mesh_desc} ---")
        print(f"memory_analysis: args={row['arg_bytes_per_dev']} temp={row['temp_bytes_per_dev']} "
              f"out={row['output_bytes_per_dev']} (per device)")
        print(f"cost_analysis: global_flops={report.hlo_flops:.3e} global_bytes={report.hlo_bytes:.3e}")
        print(f"roofline: compute={report.compute_s*1e3:.2f}ms memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms bottleneck={report.bottleneck} "
              f"useful={report.useful_flops_ratio:.3f} frac={report.roofline_fraction:.3f}")
        sys.stdout.flush()
    return row


def main() -> int:
    from repro.configs import ARCH_IDS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="write JSONL results here")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows, failures = [], []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                try:
                    row = run_cell(arch, shape, multi_pod)
                    rows.append(row)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, multi_pod, repr(e)))
                    rows.append({
                        "arch": arch, "shape": shape, "multi_pod": multi_pod,
                        "status": "FAILED", "error": repr(e),
                    })
                if args.out:
                    with open(args.out, "w") as f:
                        for r in rows:
                            f.write(json.dumps(r) + "\n")
    print(f"\n=== dry-run complete: {sum(r['status']=='ok' for r in rows)} ok, "
          f"{sum(r['status']=='skipped' for r in rows)} skipped, {len(failures)} FAILED ===")
    for f_ in failures:
        print("FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
