"""Step builders: assemble (model, optimizer, mesh, shape) into jittable
train / prefill / decode steps with full input/output shardings.

This is where the IPLS mapping becomes concrete:
    grads   -> sharded over "data" (reduce-scatter: UpdateModel)
    opt     -> sharded over "data" (responsible-agent update, ZeRO-1)
    params  -> replicated over "data" (all-gather: LoadModel), or sharded
               when fsdp=True (lightweight storage; per-layer gather in scan)
    pod axis-> replica consensus (all-reduce of aggregated updates)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec, input_specs
from repro.core.sharded import (
    DEFAULT_RULES,
    IplsStepConfig,
    init_state,
    make_train_step,
    state_shardings,
    tree_shardings,
)
from repro.launch.mesh import dp_axes, make_rules
from repro.models.sharding_hooks import activation_sharding
from repro.models.whisper import WhisperModel
from repro.optim.optimizers import Optimizer, adamw
from repro.optim.schedules import cosine_warmup


@dataclasses.dataclass
class BuiltStep:
    fn: Any                       # the python callable (pre-jit)
    in_shardings: Any
    out_shardings: Any
    arg_shapes: tuple             # ShapeDtypeStructs to .lower() with
    mesh: Mesh
    rules: Dict[str, Any]


def _batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh, rules) -> Dict[str, Any]:
    from repro.core.sharded import mesh_axis_size

    dp = rules.get("batch")
    dp_size = mesh_axis_size(mesh, dp)

    def maybe(axis_dim: int):
        return dp if axis_dim % dp_size == 0 and axis_dim >= dp_size else None

    out = {}
    for name, spec in specs.items():
        if name in ("tokens", "token"):
            out[name] = NamedSharding(mesh, P(maybe(spec.shape[0]), None))
        elif name == "participation":
            out[name] = NamedSharding(mesh, P(maybe(spec.shape[0])))
        elif name == "positions3":
            out[name] = NamedSharding(mesh, P(None, maybe(spec.shape[1]), None))
        elif name == "enc_embeds":
            out[name] = NamedSharding(mesh, P(maybe(spec.shape[0]), None, None))
        else:  # scalars (pos)
            out[name] = NamedSharding(mesh, P())
    return out


def default_optimizer(total_steps: int = 10000) -> Optimizer:
    return adamw(cosine_warmup(3e-4, 200, total_steps), wd=0.1)


# Per-arch training-step configuration (memory-driven). qwen2-vl-72b REQUIRES
# the IPLS lightweight-storage (FSDP) mode to fit v5e HBM: params stored
# partition-sharded over "data", gathered per layer inside the scan — exactly
# the paper's 'agents store only their own partitions + LoadModel on demand'.
TRAIN_OVERRIDES: Dict[str, dict] = {
    "qwen2-vl-72b": {"fsdp": True},
    "deepseek-v2-lite-16b": {"fsdp": True},
}


def build_train_step(
    model,
    mesh: Mesh,
    shape: ShapeSpec,
    optimizer: Optional[Optimizer] = None,
    step_cfg: Optional[IplsStepConfig] = None,
    extra_rules: Optional[dict] = None,
) -> BuiltStep:
    cfg = model.cfg
    optimizer = optimizer or default_optimizer()
    num_agents = 1
    for a in dp_axes(mesh):
        num_agents *= mesh.shape[a]
    step_cfg = step_cfg or IplsStepConfig()
    rules = dict(DEFAULT_RULES, **make_rules(mesh, "train"))
    rules.update(cfg.sharding_overrides)
    rules.update(extra_rules or {})

    params_shapes = model.param_shapes()
    axes = model.axes()
    state_shapes = jax.eval_shape(partial(init_state, optimizer=optimizer), params_shapes)
    state_sh = state_shardings(axes, params_shapes, optimizer, mesh, rules, fsdp=step_cfg.fsdp)
    batch_specs = input_specs(cfg, shape)
    batch_sh = _batch_shardings(batch_specs, mesh, rules)
    metrics_sh = {k: NamedSharding(mesh, P()) for k in ("loss", "grad_norm", "participation", "eps")}

    def loss_fn(params, batch):
        return model.loss(params, batch)

    # ZeRO-1 (partition-owned) layout for the in-step parameter update: the
    # LoadModel all-gather then moves after the bf16 cast (2x wire saving)
    update_sh = tree_shardings(axes, params_shapes, mesh, rules, "data")
    raw_step = make_train_step(
        loss_fn, optimizer, step_cfg, num_agents=num_agents, update_shardings=update_sh
    )

    def train_step(state, batch):
        with activation_sharding(mesh, rules):
            return raw_step(state, batch)

    return BuiltStep(
        fn=train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        arg_shapes=(state_shapes, batch_specs),
        mesh=mesh,
        rules=rules,
    )


def _cache_shapes_and_axes(model, shape: ShapeSpec):
    from repro.models.param_defs import axes_tree, shape_tree

    B, S = shape.global_batch, shape.seq_len
    if isinstance(model, WhisperModel):
        defs = model.cache_defs(B, S, S)
    else:
        defs = model.cache_defs(B, S)
    return shape_tree(defs), axes_tree(defs)


def build_prefill_step(model, mesh: Mesh, shape: ShapeSpec, extra_rules: Optional[dict] = None) -> BuiltStep:
    cfg = model.cfg
    rules = dict(DEFAULT_RULES, **make_rules(mesh, "prefill"))
    rules.update(cfg.sharding_overrides)
    rules.update(extra_rules or {})
    params_shapes = model.param_shapes()
    param_sh = tree_shardings(model.axes(), params_shapes, mesh, rules)
    batch_specs = input_specs(cfg, shape)
    batch_sh = _batch_shardings(batch_specs, mesh, rules)
    cache_shapes, cache_axes = _cache_shapes_and_axes(model, shape)
    # the cache built by prefill is stored in DECODE layout (context-parallel)
    decode_rules = dict(DEFAULT_RULES, **make_rules(mesh, "decode", shape.seq_len > 100_000))
    decode_rules.update(cfg.sharding_overrides)
    cache_sh = tree_shardings(cache_axes, cache_shapes, mesh, decode_rules)
    logits_sh = NamedSharding(mesh, P(rules.get("batch"), None, None))

    def prefill_step(params, batch):
        with activation_sharding(mesh, rules):
            return model.prefill(params, batch)

    return BuiltStep(
        fn=prefill_step,
        in_shardings=(param_sh, batch_sh),
        out_shardings=(logits_sh, cache_sh),
        arg_shapes=(params_shapes, batch_specs),
        mesh=mesh,
        rules=rules,
    )


def build_decode_step(model, mesh: Mesh, shape: ShapeSpec, extra_rules: Optional[dict] = None) -> BuiltStep:
    cfg = model.cfg
    long_ctx = shape.seq_len > 100_000
    rules = dict(DEFAULT_RULES, **make_rules(mesh, "decode", long_ctx))
    rules.update(cfg.sharding_overrides)
    rules.update(extra_rules or {})
    params_shapes = model.param_shapes()
    param_sh = tree_shardings(model.axes(), params_shapes, mesh, rules)
    cache_shapes, cache_axes = _cache_shapes_and_axes(model, shape)
    cache_sh = tree_shardings(cache_axes, cache_shapes, mesh, rules)
    batch_specs = input_specs(cfg, shape)
    batch_sh = _batch_shardings(batch_specs, mesh, rules)
    logits_sh = NamedSharding(mesh, P(rules.get("batch") if shape.global_batch > 1 else None, None, None))

    def decode_step(params, cache, batch):
        with activation_sharding(mesh, rules):
            return model.decode_step(params, cache, batch)

    return BuiltStep(
        fn=decode_step,
        in_shardings=(param_sh, cache_sh, batch_sh),
        out_shardings=(logits_sh, cache_sh),
        arg_shapes=(params_shapes, cache_shapes, batch_specs),
        mesh=mesh,
        rules=rules,
    )


def build_step(model, mesh: Mesh, shape: ShapeSpec, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(model, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(model, mesh, shape, **kw)
    return build_decode_step(model, mesh, shape, **kw)


def lower_step(built: BuiltStep):
    jitted = jax.jit(
        built.fn,
        in_shardings=built.in_shardings,
        out_shardings=built.out_shardings,
    )
    with built.mesh:
        return jitted.lower(*built.arg_shapes)
