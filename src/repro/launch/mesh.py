"""Production mesh construction.

Pure function (no module-level jax device access) so importing never locks
the device count. Single-pod: (16, 16) = ("data", "model"), 256 chips.
Multi-pod: (2, 16, 16) = ("pod", "data", "model"), 512 chips — the "pod"
axis is the IPLS replica axis (rho = number of pods).
"""
from __future__ import annotations


import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names — smoke tests exercise the
    same sharding code paths without fake devices."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel (IPLS agent) axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(mesh, shape_kind: str, long_context: bool = False) -> dict:
    """Logical->mesh rules for a given mesh and execution shape.

    train:   batch over all DP axes; sequence-parallel activations over model.
    prefill: same as train (forward only).
    decode:  batch over DP axes; KV sequence context-parallel over model —
             and over (data, model) for the batch=1 long-context shape.
    """
    dp = dp_axes(mesh)
    batch_axes = dp if len(dp) > 1 else dp[0]
    rules: dict = {"batch": batch_axes}
    if shape_kind == "decode":
        rules["kv_seq"] = ("data", "model") if long_context else "model"
        rules["act_seq"] = None  # single-token activations
    return rules
