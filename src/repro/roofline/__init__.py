from repro.roofline.analysis import (
    HW,
    HardwareSpec,
    RooflineReport,
    analyze_compiled,
    collective_bytes_from_hlo,
)

__all__ = [
    "HW",
    "HardwareSpec",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes_from_hlo",
]
