"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified empirically: a scan of 10 matmuls reports the flops of 1). Every
model here scans over layers (and microbatches), so flops/bytes/collectives
would be undercounted by up to ~80x. This module parses the post-SPMD
optimized HLO text, builds the computation call graph, infers while trip
counts from the loop condition, and multiplies costs through.

Accounting:
  * flops: dot ops only (2 flops/MAC, matching XLA's convention);
    convolutions and elementwise transcendentals are negligible for these
    workloads (no conv archs — Whisper's conv frontend is stubbed).
  * bytes: per materializing instruction (fusion boundaries): operands +
    output. Instructions inside fused computations are not materialized, so
    their bytes are skipped (their dots still count flops).
  * collectives: per-device wire bytes with ring accounting (see analysis.py),
    scaled by the enclosing loop's trip count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "u1": 1, "s1": 1,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
# one shape, optionally preceded by a /*index=N*/ comment (tuple members)
_SHAPE = re.compile(
    r"^(\(?)((?:(?:/\*index=\d+\*/\s*)?\w+\[[\d,]*\](?:\{[\d,:TS()]*\})?(?:,\s*)?)+)\)?"
)
_ONE_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_NAME = re.compile(r"^\s*(\w[\w\-]*)\(")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_COND_CONST = re.compile(r"constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_DOT_DIMS = re.compile(
    r"(?:lhs_batch_dims=\{([\d,]*)\}.*?)?lhs_contracting_dims=\{([\d,]*)\}"
)


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _ONE_SHAPE.finditer(s):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    op: str
    rest: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    # edges: (kind, callee) with kind in {while_body, while_cond, call}
    edges: List[Tuple[str, str, Optional[int]]] = dataclasses.field(default_factory=list)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = _COMP_HEADER.match(line.strip()) if line.strip().endswith("{") else None
        if hm:
            cur = Computation(hm.group(2))
            comps[cur.name] = cur
            if hm.group(1):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        sm = _SHAPE.match(rhs)
        if not sm:
            continue
        shape_str = sm.group(0)
        rest = rhs[len(shape_str):].strip()
        opm = _OP_NAME.match(rest)
        op = opm.group(1) if opm else rest.split("(")[0].strip()
        out_shapes = _parse_shapes(shape_str)
        operands = _OPERANDS.findall(rest)
        instr = Instr(name=name, out_shapes=out_shapes, op=op, rest=rest, operands=operands)
        cur.instrs.append(instr)
        wm = _WHILE.search(rest)
        if wm:
            tm = _TRIP.search(rest)
            trips = int(tm.group(1)) if tm else None
            cur.edges.append(("while_cond", wm.group(1), trips))
            cur.edges.append(("while_body", wm.group(2), trips))
        else:
            for callee in _CALLS.findall(rest):
                cur.edges.append(("call", callee, None))
    if entry is None:
        # fall back: first computation
        entry = next(iter(comps)) if comps else ""
    comps["__entry__"] = comps.get(entry, Computation("__entry__"))
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the constant compared
    against the induction variable. Falls back to 1."""
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant":
            m = _COND_CONST.search(ins.rest)
            if m:
                consts.append(int(m.group(1)))
        if ins.op == "compare":
            pass
    return max(consts) if consts else 1


def _dot_flops(ins: Instr, shapes_by_name: Dict[str, List[Tuple[str, Tuple[int, ...]]]]) -> float:
    """2 * batch * M * N * K from the dot dimension numbers."""
    dm = _DOT_DIMS.search(ins.rest)
    ops = [o for o in ins.operands if o in shapes_by_name]
    if len(ops) < 2:
        return 0.0
    lhs = shapes_by_name[ops[0]][0][1] if shapes_by_name[ops[0]] else ()
    out_elems = 1
    for dt, dims in ins.out_shapes:
        for d in dims:
            out_elems *= d
        break
    if dm is None:
        # scalar-ish dot; approximate with output elements
        return 2.0 * out_elems
    lcontract = [int(x) for x in (dm.group(2) or "").split(",") if x]
    k = 1
    for idx in lcontract:
        if idx < len(lhs):
            k *= lhs[idx]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


_COLLECTIVES = {
    "all-gather": "all-gather",
    "all-gather-start": "all-gather",
    "all-reduce": "all-reduce",
    "all-reduce-start": "all-reduce",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
}


def analyze_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry_name = comps.get("__entry_name__")
    entry = comps["__entry__"]

    # a computation called from a fusion instruction is fused (its tensors
    # are not materialized; bytes are accounted at the fusion call site)
    fused: set = set()
    for c in comps.values():
        if not isinstance(c, Computation):
            continue
        for ins in c.instrs:
            if ins.op == "fusion":
                for callee in _CALLS.findall(ins.rest):
                    fused.add(callee)

    # multipliers via DFS over the call graph
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if name not in comps or not isinstance(comps[name], Computation) or depth > 50:
            return
        mult[name] += m
        c = comps[name]
        for i, (kind, callee, trips) in enumerate(c.edges):
            if kind == "while_body":
                if trips is None:
                    cond_name = (
                        c.edges[i - 1][1]
                        if i > 0 and c.edges[i - 1][0] == "while_cond"
                        else None
                    )
                    trips = (
                        _trip_count(comps[cond_name])
                        if cond_name and cond_name in comps
                        else 1
                    )
                visit(callee, m * max(trips, 1), depth + 1)
            elif kind == "while_cond":
                visit(callee, m * max(trips or 1, 1), depth + 1)
            else:
                visit(callee, m, depth + 1)

    if entry_name:
        visit(entry_name, 1.0)

    cost = HloCost()
    for cname, m in mult.items():
        c = comps.get(cname)
        if not isinstance(c, Computation):
            continue
        shapes_by_name = {ins.name: ins.out_shapes for ins in c.instrs}
        materializes = cname not in fused
        for ins in c.instrs:
            if ins.op == "dot":
                cost.flops += m * _dot_flops(ins, shapes_by_name)
            kind = _COLLECTIVES.get(ins.op)
            if kind is not None:
                out_b = _shape_bytes(ins.out_shapes)
                oper_b = sum(
                    _shape_bytes(shapes_by_name.get(o, [])) for o in ins.operands
                )
                if kind == "all-gather":
                    wire = out_b
                elif kind == "reduce-scatter":
                    wire = oper_b
                elif kind == "all-reduce":
                    wire = 2 * out_b
                elif kind == "all-to-all":
                    wire = max(oper_b, out_b)
                else:  # collective-permute
                    wire = out_b
                cost.collective_bytes[kind] += m * wire
            # "copy" is excluded: XLA-CPU materializes while-loop carries with
            # explicit copies (including whole stacked-parameter trees, x trip
            # count); on TPU these buffers alias and never touch HBM.
            if materializes and ins.op not in (
                "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                "while", "after-all", "copy",
            ):
                out_b = _shape_bytes(ins.out_shapes)
                oper_b = sum(
                    _shape_bytes(shapes_by_name.get(o, [])) for o in ins.operands
                )
                cost.bytes += m * (out_b + oper_b)
    return cost
