"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum the effective per-device wire bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
using ring-algorithm accounting:

    all-gather:        output_bytes   (each chip receives ~N(1-1/n))
    reduce-scatter:    input_bytes    (each chip sends ~N(1-1/n))
    all-reduce:        2 * input_bytes (RS + AG phases)
    all-to-all:        input_bytes
    collective-permute: operand bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values from the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link


HW = HardwareSpec()


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like ``bf16[8,4096,128]``; tuples are
    handled by the caller summing each element."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


# matches: %x = TYPE[...] all-gather(...), or fusion roots containing
# collective ops; post-SPMD optimized HLO has one instruction per line.
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\b(.*)$"
)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring accounting)."""
    out: Dict[str, float] = {
        "all-gather": 0.0,
        "all-reduce": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        out_shape, kind, rest = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        out_bytes = _shape_bytes(out_shape)
        # operand shapes appear in the argument list of the call
        operand_bytes = _shape_bytes(rest)
        if kind == "all-gather":
            out[kind] += out_bytes
        elif kind == "reduce-scatter":
            out[kind] += operand_bytes
        elif kind == "all-reduce":
            out[kind] += 2 * out_bytes
        elif kind == "all-to-all":
            out[kind] += operand_bytes if operand_bytes else out_bytes
        elif kind == "collective-permute":
            out[kind] += out_bytes
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # global FLOPs (cost_analysis is per-program)
    hlo_bytes: float
    collective_bytes: Dict[str, float]
    model_flops: float               # 6 * N_active * tokens
    peak_bytes_per_device: Optional[float] = None
    hw: HardwareSpec = dataclasses.field(default_factory=lambda: HW)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.hw.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * self.hw.hbm_bw)

    @property
    def collective_s(self) -> float:
        # collective bytes are already per-device wire bytes
        return sum(self.collective_bytes.values()) / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU at the roofline step time."""
        return self.model_flops / (self.chips * self.hw.peak_flops * max(self.step_time_s, 1e-12))

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.peak_bytes_per_device,
        }


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    model_flops: float,
    hw: HardwareSpec = HW,
) -> RooflineReport:
    # NOTE: compiled.cost_analysis() counts while-loop bodies ONCE (verified),
    # which would undercount scanned-layer models by up to ~80x. We use our
    # trip-count-aware HLO analyzer instead (repro/roofline/hlo_cost.py). The
    # compiled module is the per-device SPMD program: flops/bytes are
    # per-device; multiply by chips for the global numbers.
    from repro.roofline.hlo_cost import analyze_hlo_text

    hlo_text = compiled.as_text()
    cost = analyze_hlo_text(hlo_text)
    flops = float(cost.flops)
    byts = float(cost.bytes)
    coll = dict(cost.collective_bytes)
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = None
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        hlo_flops=flops * chips,
        hlo_bytes=byts * chips,
        collective_bytes=coll,
        model_flops=model_flops,
        peak_bytes_per_device=peak,
        hw=hw,
    )


def model_flops_for(model, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (fwd)."""
    n = model.num_active_params()
    if shape_kind == "train":
        return 6.0 * n * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    # decode: one token per sequence
    return 2.0 * n * global_batch
