"""Serving example: batched prefill + decode loop with the serving cache
(the decode_32k / long_500k path at smoke scale), including the context-
parallel cache layout used on the production mesh.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens

    batch = {
        "tokens": jnp.asarray(rng.integers(0, 255, (B, P)), jnp.int32),
        "cache_len": cache_len,
    }
    if getattr(cfg, "mrope", False):
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(P)[None, None, :], (3, B, P)
        ).astype(jnp.int32)
    if cfg.name.startswith("whisper"):
        batch["enc_embeds"] = jnp.asarray(rng.standard_normal((B, P, cfg.d_model)), jnp.bfloat16)

    t0 = time.time()
    logits, cache = model.prefill(params, batch)
    print(f"prefill({B}x{P}) -> logits {logits.shape} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, cache, {"token": tok, "pos": jnp.asarray(P + i, jnp.int32)})
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    out = np.concatenate(generated, axis=1)
    print(f"decoded {args.tokens} tokens/seq x {B} seqs in {dt:.2f}s "
          f"({args.tokens*B/max(dt,1e-9):.1f} tok/s on CPU smoke config)")
    print("first sequence:", out[0][:16], "...")


if __name__ == "__main__":
    main()
