"""End-to-end driver: train a reduced LM for a few hundred steps through the
FULL production path — model zoo config, IPLS train step (eps-weighted
RS/update/AG semantics), sharded optimizer, checkpointing, restart.

    PYTHONPATH=src python examples/train_lm_smoke.py --arch internlm2-1.8b --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import build_model, get_config
from repro.configs.registry import ShapeSpec
from repro.core.sharded import IplsStepConfig, init_state
from repro.data import synth_tokens
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import build_train_step
from repro.optim import adamw, cosine_warmup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/ipls_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    mesh = make_smoke_mesh()
    shape = ShapeSpec("smoke_train", seq_len=args.seq, global_batch=args.batch, kind="train")
    opt = adamw(cosine_warmup(3e-3, 20, args.steps), wd=0.01)
    built = build_train_step(model, mesh, shape, optimizer=opt, step_cfg=IplsStepConfig())

    state = init_state(model.init(0), opt)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        try:
            host = jax.tree.map(np.asarray, state)
            restored, start = mgr.restore_latest(host)
            state = jax.tree.map(jnp.asarray, restored)
            from repro.core.sharded import IplsTrainState
            state = IplsTrainState(*state)
            print(f"resumed from step {start}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    data = synth_tokens(4096, args.seq, min(cfg.vocab, 256), seed=0)
    step_fn = jax.jit(built.fn, in_shardings=built.in_shardings, out_shardings=built.out_shardings)
    rng = np.random.default_rng(0)
    t0 = time.time()
    with built.mesh:
        for i in range(start, args.steps):
            sel = rng.integers(0, len(data), args.batch)
            batch = {
                "tokens": jnp.asarray(data[sel], jnp.int32),
                "participation": jnp.ones((args.batch,), jnp.float32),
            }
            state, metrics = step_fn(state, batch)
            if i % 20 == 0 or i == args.steps - 1:
                print(
                    f"step {i:4d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} eps={float(metrics['eps']):.3f} "
                    f"({(time.time()-t0):.1f}s)"
                )
            if i > 0 and i % 100 == 0:
                mgr.save_async(jax.tree.map(np.asarray, state), step=i)
    mgr.wait()
    print("done; final loss should be well below the ~5.5 random-init level")


if __name__ == "__main__":
    main()
