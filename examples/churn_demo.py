"""Fault-tolerance demo: agents leave, crash, disconnect and rejoin while
training continues (paper §2.2 Terminate + Fig 3b).

    PYTHONPATH=src python examples/churn_demo.py
    PYTHONPATH=src python examples/churn_demo.py --engine vectorized --scan-rounds 7
    PYTHONPATH=src python examples/churn_demo.py --metrics-out churn.jsonl --trace-out churn.trace.json

Both engines run the same membership-event schedule: the vectorized engine
replays each event round on its embedded scalar oracle and re-snapshots the
dense planes at the boundary (docs/ENGINE.md "Churn re-snapshot"), so with
--engine vectorized the demo runs the real schedule fused — optionally
lax.scan-windowed — and then re-runs it on the scalar engine to assert the
final accuracies match.
"""
import argparse

from repro.data import iid_split, synth_mnist
from repro.fl import SimConfig, make_simulation
from repro.p2p.network import LOSSY

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--engine", default="scalar", choices=["scalar", "vectorized"],
        help="round engine; vectorized runs the same churn schedule via "
        "event-boundary re-snapshot and is verified against the scalar oracle",
    )
    ap.add_argument(
        "--scan-rounds", type=int, default=0,
        help="vectorized only: fuse this many rounds per lax.scan device call",
    )
    ap.add_argument(
        "--telemetry", action="store_true",
        help="record the per-round metric stream (docs/TELEMETRY.md)",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write the metric stream as JSONL (implies --telemetry)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Chrome trace-event JSON timeline (implies --telemetry); "
        "open at https://ui.perfetto.dev",
    )
    args = ap.parse_args()
    telemetry = args.telemetry or bool(args.metrics_out or args.trace_out)

    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=8000, num_test=2000, seed=0)
    shards = iid_split(x_tr, y_tr, num_agents=6, seed=0)

    churn = {
        3: [(5, "offline")],              # agent 5 loses connectivity
        5: [(4, "leave")],                # agent 4 leaves gracefully (Terminate)
        7: [(5, "online")],               # agent 5 rejoins (with memory)
        9: [(3, "crash")],                # agent 3 fails without handoff
    }
    cfg = SimConfig(
        num_agents=6, num_partitions=12, pi=3, rho=2, rounds=14,
        local_iters=8, churn=churn, memory=True, conditions=LOSSY,
        engine=args.engine, scan_rounds=args.scan_rounds,
        telemetry=telemetry, trace=bool(args.trace_out),
    )
    sim = make_simulation(cfg, shards, x_te, y_te)
    for m in sim.run():
        rnd = m["round"]
        events = ",".join(a for _, a in churn.get(rnd, [])) or "-"
        print(
            f"round {rnd:2d} active={m['active']} acc={m['acc_mean']:.4f} "
            f"(+/-{m['acc_std']:.4f}) churn=[{events}]"
        )
    assert sim.table.coverage(), "partition coverage lost!"
    print("\npartition coverage preserved through leave/crash/rejoin ✓")
    if args.engine == "vectorized":
        print(f"device dispatches: {sim.device_dispatches} for {cfg.rounds} rounds")
        # same schedule on the scalar oracle: the re-snapshot path must land
        # on the identical final accuracy (weights match to float noise)
        import dataclasses

        ref = make_simulation(
            dataclasses.replace(
                cfg, engine="scalar", scan_rounds=0, telemetry=False, trace=False
            ),
            shards, x_te, y_te,
        )
        ref_acc = ref.run()[-1]["acc_mean"]
        acc = sim.history[-1]["acc_mean"]
        assert abs(acc - ref_acc) < 1e-6, (acc, ref_acc)
        print(f"scalar-oracle check: final acc {acc:.4f} == {ref_acc:.4f} ✓")
    if args.metrics_out:
        sim.recorder.write_jsonl(
            args.metrics_out,
            meta={"example": "churn_demo", "engine": args.engine},
        )
        print(f"metrics stream -> {args.metrics_out}")
    if args.trace_out:
        sim.recorder.trace.write(args.trace_out)
        print(f"trace timeline -> {args.trace_out} (open in perfetto)")

if __name__ == "__main__":
    main()
