"""Fault-tolerance demo: agents leave, crash, disconnect and rejoin while
training continues (paper §2.2 Terminate + Fig 3b).

    PYTHONPATH=src python examples/churn_demo.py
"""
from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig
from repro.p2p.network import LOSSY

def main():
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=8000, num_test=2000, seed=0)
    shards = iid_split(x_tr, y_tr, num_agents=6, seed=0)

    churn = {
        3: [(5, "offline")],              # agent 5 loses connectivity
        5: [(4, "leave")],                # agent 4 leaves gracefully (Terminate)
        7: [(5, "online")],               # agent 5 rejoins (with memory)
        9: [(3, "crash")],                # agent 3 fails without handoff
    }
    cfg = SimConfig(
        num_agents=6, num_partitions=12, pi=3, rho=2, rounds=14,
        local_iters=8, churn=churn, memory=True, conditions=LOSSY,
    )
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    for rnd in range(cfg.rounds):
        m = sim.run_round(rnd)
        events = ",".join(a for _, a in churn.get(rnd, [])) or "-"
        print(
            f"round {rnd:2d} active={m['active']} acc={m['acc_mean']:.4f} "
            f"(+/-{m['acc_std']:.4f}) churn=[{events}]"
        )
    assert sim.table.coverage(), "partition coverage lost!"
    print("\npartition coverage preserved through leave/crash/rejoin ✓")

if __name__ == "__main__":
    main()
