"""Quickstart: decentralized federated training with IPLS in ~40 lines.

Boots 5 agents on the simulated IPFS substrate, trains the paper's MLP on a
synthetic MNIST-like dataset for 10 rounds, and compares against the
centralized FedAvg baseline.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --engine vectorized --scan-rounds 5
    PYTHONPATH=src python examples/quickstart.py --wire-dtype int8
    PYTHONPATH=src python examples/quickstart.py --metrics-out run.jsonl --trace-out run.trace.json

--wire-dtype int8 ships deltas and partition transfers as int8 codes with
per-block power-of-two scales and error feedback (~4x less wire traffic,
accuracy within noise of f32 — see docs/ENGINE.md).

Choosing --scan-rounds: W > 1 fuses W rounds into one ``lax.scan`` device
call (vectorized engine only), cutting per-round dispatch to 1/W — the win
grows as the model shrinks and rounds get cheaper. Larger W compiles a
longer program and reports metrics only at window boundaries; W that
divides ``rounds`` avoids one extra jit specialization for the tail
window. W=5..10 is a good default; results are identical for any W
(see tests/test_scan.py).
"""
import argparse

from repro.data import iid_split, synth_mnist
from repro.fl import SimConfig, make_simulation, run_centralized

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--engine", default="scalar", choices=["scalar", "vectorized"],
        help="round engine: per-agent pubsub oracle or batched device calls",
    )
    ap.add_argument(
        "--scan-rounds", type=int, default=0,
        help="vectorized only: fuse this many rounds per lax.scan device call",
    )
    ap.add_argument(
        "--wire-dtype", default="f32", choices=["f32", "int8"],
        help="wire transport: raw f32 or int8 + error feedback (~4x less traffic)",
    )
    ap.add_argument(
        "--telemetry", action="store_true",
        help="record the per-round metric stream (docs/TELEMETRY.md)",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write the metric stream as JSONL (implies --telemetry); "
        "summarize with `python -m repro.telemetry.report PATH`",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Chrome trace-event JSON timeline (implies --telemetry); "
        "open at https://ui.perfetto.dev",
    )
    args = ap.parse_args()
    telemetry = args.telemetry or bool(args.metrics_out or args.trace_out)

    # 1. data: 60k synthetic MNIST-like samples, split IID over 5 agents
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=10000, num_test=2000, seed=0)
    shards = iid_split(x_tr, y_tr, num_agents=5, seed=0)

    # 2. IPLS: 10 model partitions, each agent responsible for >=2 (pi),
    #    each partition replicated at most twice (rho)
    cfg = SimConfig(
        num_agents=5, num_partitions=10, pi=2, rho=2,
        rounds=10, local_iters=10, batch_size=128,
        engine=args.engine, scan_rounds=args.scan_rounds,
        wire_dtype=args.wire_dtype,
        telemetry=telemetry, trace=bool(args.trace_out),
    )
    sim = make_simulation(cfg, shards, x_te, y_te)
    history = sim.run()
    if args.metrics_out:
        sim.recorder.write_jsonl(
            args.metrics_out,
            meta={"example": "quickstart", "engine": args.engine,
                  "wire_dtype": args.wire_dtype},
        )
        print(f"metrics stream -> {args.metrics_out}")
    if args.trace_out:
        sim.recorder.trace.write(args.trace_out)
        print(f"trace timeline -> {args.trace_out} (open in perfetto)")

    # 3. centralized FedAvg reference on the same shards
    central = run_centralized(shards, x_te, y_te, rounds=10, local_iters=10)

    print(f"{'round':>5} {'IPLS acc':>10} {'central acc':>12}")
    for h, c in zip(history, central):
        print(f"{h['round']:>5} {h['acc_mean']:>10.4f} {c['acc_mean']:>12.4f}")
    drop = (central[-1]["acc_mean"] - history[-1]["acc_mean"]) * 1000
    print(f"\naccuracy drop due to decentralisation: {drop:.2f} per-mille")
    if args.engine == "vectorized":
        print(f"total bytes over the (simulated) wire: {sim._bytes_total/1e6:.1f} MB")
        print(f"device dispatches: {sim.device_dispatches} for {cfg.rounds} rounds")
    else:
        print(f"total bytes over the (simulated) wire: {sim.net.pubsub.total_bytes()/1e6:.1f} MB")

if __name__ == "__main__":
    main()
