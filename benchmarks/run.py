"""Benchmark entry point: one function per paper table/figure + the roofline
and kernel harnesses. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes (CI)")
    ap.add_argument(
        "--only", default=None,
        help="comma list of: convergence,fault,scalability,roofline,kernels,rounds",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (
        bench_convergence,
        bench_fault_tolerance,
        bench_kernels,
        bench_roofline,
        bench_rounds,
        bench_scalability,
    )

    print("name,us_per_call,derived")
    sys.stdout.flush()
    t0 = time.time()
    # one runner-stamped timestamp for every artifact this invocation writes
    stamp = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())

    def want(name):
        return only is None or name in only

    if want("kernels"):
        for r in bench_kernels.run():
            print(r)
        sys.stdout.flush()
    if want("rounds"):
        rounds = 2 if args.quick else 4
        counts = (10, 32) if args.quick else (10, 32, 100)
        lossy_counts = (10,) if args.quick else (10, 32)
        # BENCH_rounds.json lives at the repo root: it is the persisted perf
        # trajectory for the round engines and is uploaded as a CI artifact
        for r in bench_rounds.run(
            rounds=rounds, agent_counts=counts, lossy_agent_counts=lossy_counts,
            out_json="BENCH_rounds.json", timestamp=stamp,
        ):
            print(r)
        sys.stdout.flush()
    if want("roofline"):
        for r in bench_roofline.run():
            print(r)
        sys.stdout.flush()
    if want("scalability"):
        rounds = 2 if args.quick else 3
        for r in bench_scalability.run(rounds=rounds, out_json="benchmarks/out_scalability.json"):
            print(r)
        sys.stdout.flush()
    if want("fault"):
        rounds = 8 if args.quick else 30
        for r in bench_fault_tolerance.run(rounds=rounds, out_json="benchmarks/out_fault.json"):
            print(r)
        sys.stdout.flush()
    if want("convergence"):
        rounds = 6 if args.quick else 40
        counts = (5,) if args.quick else (10, 25, 50)
        for r in bench_convergence.run(
            rounds=rounds, agent_counts=counts,
            out_json="benchmarks/out_convergence.json", timestamp=stamp,
        ):
            print(r)
        sys.stdout.flush()
    print(f"# total_wall_s={time.time()-t0:.1f}")


if __name__ == "__main__":
    main()
