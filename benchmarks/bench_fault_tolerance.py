"""Paper Fig 3a/3b: fault tolerance.

3a: 8 agents — rho=1 perfect, rho=4 perfect, rho=4 imperfect connectivity;
    the paper reports rho=4 converging with higher variance, and degraded
    accuracy under imperfect connectivity.
3b: half the agents disconnect mid-training and rejoin — 'training with
    memory' (keep cached partitions) vs 'memoryless' (cold cache).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import csv_row, load_data, save_json
from repro.data import iid_split
from repro.fl import IPLSSimulation, SimConfig
from repro.p2p.network import LOSSY, PERFECT


def run(rounds: int = 30, out_json: str | None = None) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data(num_train=24000)
    n = 8
    shards = iid_split(x_tr, y_tr, n, seed=0)
    rows: List[str] = []
    results = {}

    # --- Fig 3a: rho x connectivity --------------------------------------
    for tag, rho, cond in (
        ("rho1_perfect", 1, PERFECT),
        ("rho4_perfect", 4, PERFECT),
        ("rho4_imperfect", 4, LOSSY),
    ):
        t0 = time.time()
        cfg = SimConfig(
            num_agents=n, num_partitions=8, pi=2, rho=rho, rounds=rounds,
            local_iters=10, conditions=cond, eval_agents=5,
        )
        hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
        accs = [h["acc_mean"] for h in hist]
        stds = [h["acc_std"] for h in hist]
        results[tag] = {"acc": accs, "std": stds}
        rows.append(
            csv_row(
                f"fig3a_{tag}",
                (time.time() - t0) / rounds * 1e6,
                f"final_acc={accs[-1]:.4f};mean_std={np.mean(stds[5:]):.4f}",
            )
        )

    # --- Fig 3b: churn, memory vs memoryless ------------------------------
    # half the agents disconnect at round 8, rejoin at round 16
    churn = {8: [(a, "offline") for a in range(n // 2)],
             16: [(a, "online") for a in range(n // 2)]}
    for tag, memory in (("with_memory", True), ("memoryless", False)):
        t0 = time.time()
        cfg = SimConfig(
            num_agents=n, num_partitions=8, pi=2, rho=2, rounds=rounds,
            local_iters=10, churn=churn, memory=memory, eval_agents=5,
        )
        hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
        accs = [h["acc_mean"] for h in hist]
        stds = [h["acc_std"] for h in hist]
        # variation during/after the outage window (paper: memory run is calmer)
        var_window = float(np.mean(stds[8:20]))
        results[tag] = {"acc": accs, "std": stds}
        rows.append(
            csv_row(
                f"fig3b_{tag}",
                (time.time() - t0) / rounds * 1e6,
                f"final_acc={accs[-1]:.4f};outage_window_std={var_window:.4f}",
            )
        )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
