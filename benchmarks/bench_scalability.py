"""Paper §3 'Scalability and storage requirements': per-agent traffic per
round is ~constant in |A| and bounded by ~2|M|; gossip traffic grows with
fanout; per-agent storage is k_i/K of the model."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import csv_row, load_data, save_json
from repro.data import iid_split
from repro.fl import IPLSSimulation, SimConfig, run_gossip
from repro.models import mlp_mnist
from repro.core.partition import flatten_params


def run(rounds: int = 3, agent_counts=(5, 10, 20, 40), out_json: str | None = None) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data(num_train=12000)
    w0, _ = flatten_params(mlp_mnist.init_params(0))
    M_bytes = w0.nbytes
    rows: List[str] = []
    results = {"model_bytes": int(M_bytes)}

    for n in agent_counts:
        shards = iid_split(x_tr, y_tr, n, seed=0)
        t0 = time.time()
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2, rounds=rounds,
            local_iters=2, eval_agents=2,
        )
        sim = IPLSSimulation(cfg, shards, x_te, y_te)
        sim.run()
        per_agent_round = sim.net.pubsub.total_bytes() / n / rounds
        # storage: bytes of owned partitions per agent
        store = [
            sum(st.value.nbytes for st in ag.owned.values()) for ag in sim.agents.values()
        ]
        results[f"ipls_n{n}"] = {
            "per_agent_bytes_per_round": per_agent_round,
            "ratio_to_2M": per_agent_round / (2 * M_bytes),
            "mean_storage_fraction": float(np.mean(store) / M_bytes),
        }
        rows.append(
            csv_row(
                f"scalability_ipls_n{n}",
                (time.time() - t0) / rounds * 1e6,
                f"per_agent_MBpr={per_agent_round/1e6:.2f};x2M={per_agent_round/(2*M_bytes):.2f};"
                f"storage_frac={np.mean(store)/M_bytes:.2f}",
            )
        )

    # gossip comparison at n=10 (paper §4: IPLS transmits less than gossip)
    shards = iid_split(x_tr, y_tr, 10, seed=0)
    t0 = time.time()
    hist = run_gossip(shards, x_te, y_te, rounds=rounds, fanout=2, local_iters=2)
    gossip_per_agent = hist[-1]["bytes_total"] / 10 / rounds
    results["gossip_n10"] = {"per_agent_bytes_per_round": gossip_per_agent}
    rows.append(
        csv_row(
            "scalability_gossip_n10_fanout2",
            (time.time() - t0) / rounds * 1e6,
            f"per_agent_MBpr={gossip_per_agent/1e6:.2f};x2M={gossip_per_agent/(2*M_bytes):.2f}",
        )
    )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
