"""Kernel micro-harness: wall-time of the pure-jnp oracle paths on CPU (the
kernels themselves are TPU-target; interpret-mode timing is not meaningful),
plus the DERIVED HBM-traffic model of each Pallas kernel vs its XLA path —
the quantity the §Perf memory-term arguments use."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))  # single warmup call (block handles pytrees)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run() -> List[str]:
    rows: List[str] = []
    rng = np.random.default_rng(0)

    # ipls_aggregate: XLA ref timing + traffic model
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_ref

    N, R = 1_000_000, 8
    w = jnp.asarray(rng.standard_normal(N), jnp.float32)
    d = jnp.asarray(rng.standard_normal((R, N)), jnp.float32)
    m = jnp.ones((R,), jnp.float32)
    eps = jnp.asarray(0.7, jnp.float32)
    f = jax.jit(ipls_aggregate_ref)
    us = _time(f, w, d, m, eps)
    # fused kernel HBM traffic: read (R+1)N + write N floats; XLA unfused
    # pays an extra round-trip for the reduction intermediate
    fused = (R + 2) * N * 4
    unfused = (R + 2) * N * 4 + 2 * N * 4
    rows.append(
        csv_row(
            "kernel_ipls_aggregate_n1e6_r8",
            us,
            f"fused_hbm_MB={fused/1e6:.1f};xla_hbm_MB={unfused/1e6:.1f};saving={1-fused/unfused:.2%}",
        )
    )

    # flash attention: ref timing at a train-ish tile + traffic model
    from repro.kernels.flash_attention.ref import mha_ref

    B, H, S, D = 1, 4, 1024, 128
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    f = jax.jit(lambda q: mha_ref(q, q, q))
    us = _time(f, q)
    naive = (2 * B * H * S * S * 4) + 4 * B * H * S * D * 2  # logits+probs round trip
    flash = 4 * B * H * S * D * 2
    rows.append(
        csv_row(
            "kernel_flash_attention_s1024_d128",
            us,
            f"flash_hbm_MB={flash/1e6:.2f};xla_hbm_MB={naive/1e6:.2f};saving={1-flash/naive:.2%}",
        )
    )

    # decode attention traffic model (per token, per layer)
    S, B, H, D = 32768, 8, 8, 128
    kv_bytes = 2 * B * S * H * D * 2
    rows.append(
        csv_row(
            "kernel_decode_attention_s32k",
            0.0,
            f"kv_stream_MB={kv_bytes/1e6:.0f};ideal_ms_at_819GBs={kv_bytes/819e9*1e3:.2f}",
        )
    )

    # rwkv6 linear scan: XLA chunked path vs kernel traffic model
    from repro.models.ssm import rwkv6_chunked

    B, T, H, K = 1, 512, 4, 64
    r = jnp.asarray(rng.standard_normal((B, T, H, K)) * 0.5, jnp.float32)
    lw = jnp.asarray(-np.exp(rng.standard_normal((B, T, H, K)) * 0.5), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)) * 0.1, jnp.float32)
    f = jax.jit(lambda r, lw: rwkv6_chunked(r, r, r, lw, u, 64)[0])
    us = _time(f, r, lw)
    Q = 64
    xla_pair_bytes = (T // Q) * Q * Q * H * K * 4  # materialized pair tensor
    kernel_bytes = 4 * B * T * H * K * 4  # r,k,v,logw single read
    rows.append(
        csv_row(
            "kernel_rwkv6_scan_t512",
            us,
            f"kernel_hbm_MB={kernel_bytes/1e6:.1f};xla_pair_MB={xla_pair_bytes/1e6:.1f};"
            f"saving={1-kernel_bytes/(kernel_bytes+xla_pair_bytes):.2%}",
        )
    )

    # quantize: compression ratio for the WAN/compressed-RS path
    rows.append(
        csv_row(
            "kernel_quantize_int8",
            0.0,
            "wire_reduction=4x_vs_f32;2x_vs_bf16;ef_keeps_unbiased=true",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
