"""Round-engine throughput: scalar (per-agent Python loops) vs vectorized
(a few batched device calls per round), same SimConfig, PERFECT and LOSSY
networks.

Reports rounds/sec and agent*rounds/sec at A in {10, 32, 100} — the paper's
scalability story is per-agent work staying constant, so agent*rounds/sec is
the number that must GROW with A for the simulator to reach paper-scale
agent counts. The LOSSY rows measure the mask-stream path (pre-drawn
loss/delay fates + delta ring buffer), i.e. the scenario that previously
forced the scalar engine. The first round per engine is excluded (jit
compile + warm-up); both engines then run the same number of timed rounds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from benchmarks.common import csv_row, load_data, save_json
from repro.data import iid_split
from repro.fl import SimConfig, make_simulation
from repro.p2p.network import LOSSY, PERFECT


def _time_engine(engine: str, shards, x_te, y_te, cfg: SimConfig, rounds: int) -> float:
    """Seconds per round, steady state (construction + warm-up round excluded)."""
    sim = make_simulation(dataclasses.replace(cfg, engine=engine), shards, x_te, y_te)
    sim.run_round(0)  # warm-up: jit compile, buffer growth
    t0 = time.time()
    for r in range(1, rounds + 1):
        sim.run_round(r)
    return (time.time() - t0) / rounds


def run(
    rounds: int = 4,
    agent_counts=(10, 32, 100),
    lossy_agent_counts=(10, 32),
    out_json: str | None = None,
) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data(num_train=12000, num_test=800)
    rows: List[str] = []
    results = {}
    variants = [("", PERFECT, agent_counts), ("_lossy", LOSSY, lossy_agent_counts)]
    for tag, cond, counts in variants:
        for n in counts:
            shards = iid_split(x_tr, y_tr, n, seed=0)
            cfg = SimConfig(
                num_agents=n, num_partitions=10, pi=2, rho=2,
                local_iters=2, batch_size=64, eval_agents=4, conditions=cond,
            )
            s_scalar = _time_engine("scalar", shards, x_te, y_te, cfg, rounds)
            s_vec = _time_engine("vectorized", shards, x_te, y_te, cfg, rounds)
            speedup = s_scalar / s_vec
            results[f"n{n}{tag}"] = {
                "scalar_rounds_per_s": 1.0 / s_scalar,
                "vectorized_rounds_per_s": 1.0 / s_vec,
                "speedup": speedup,
            }
            rows.append(
                csv_row(
                    f"rounds_scalar{tag}_n{n}",
                    s_scalar * 1e6,
                    f"rounds_per_s={1/s_scalar:.2f};agent_rounds_per_s={n/s_scalar:.1f}",
                )
            )
            rows.append(
                csv_row(
                    f"rounds_vectorized{tag}_n{n}",
                    s_vec * 1e6,
                    f"rounds_per_s={1/s_vec:.2f};agent_rounds_per_s={n/s_vec:.1f};"
                    f"speedup_vs_scalar={speedup:.1f}x",
                )
            )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
