"""Round-engine throughput: scalar (per-agent Python loops) vs vectorized
(a few batched device calls per round) vs scanned (one ``lax.scan`` device
call per ``scan_rounds`` window), same SimConfig, PERFECT and LOSSY networks.

Reports rounds/sec and agent*rounds/sec at A in {10, 32, 100} — the paper's
scalability story is per-agent work staying constant, so agent*rounds/sec is
the number that must GROW with A for the simulator to reach paper-scale
agent counts. The LOSSY rows measure the mask-stream path (pre-drawn
loss/delay fates + delta ring buffer), i.e. the scenario that previously
forced the scalar engine. The scanned rows measure the multi-round fused
path whose per-round device dispatches drop to ~1/W of the unscanned
vectorized engine (``dispatches_per_round`` in the derived column).

Timing discipline: ``time.perf_counter`` (monotonic, high resolution), the
warm-up covers one full scan window so jit compile never lands in the
steady-state measurement, and the last device output is
``jax.block_until_ready``-synced before the timer stops so async dispatch
cannot leak timed work past the stop.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import List, Tuple

import jax

from benchmarks.common import csv_row, load_data, save_json
from repro.analysis import analyze_paths
from repro.data import iid_split
from repro.fl import SimConfig, make_simulation
from repro.p2p.network import LOSSY, PERFECT
from repro.telemetry import host_metadata

SCAN_W = 8  # window size for the scanned variant (matches acceptance bar)


def _sync(sim) -> None:
    """Block until the engine's device-resident state is materialized.

    The vectorized engines dispatch asynchronously; without an explicit sync
    the timer stops while device work is still in flight. The scalar engine
    keeps no persistent device arrays (its per-round host pulls already
    synchronize), so this is a no-op there.
    """
    for name in ("_V_pre", "_V_merged", "_Vl", "_C"):
        v = getattr(sim, name, None)
        if v is not None:
            jax.block_until_ready(v)


def _time_engine(
    engine: str, shards, x_te, y_te, cfg: SimConfig, rounds: int, scan: int = 0
) -> Tuple[float, float]:
    """(seconds per round, device dispatches per round), steady state.

    Warm-up runs one full scan window (or one round when unscanned) so jit
    compile and buffer growth are excluded; the timed section then covers a
    whole number of windows.
    """
    warm = scan if scan else 1
    timed = rounds
    if scan:  # timed section must be a whole number of windows
        timed = ((max(rounds, scan) + scan - 1) // scan) * scan
    cfg = dataclasses.replace(
        cfg, engine=engine, scan_rounds=scan, rounds=warm + timed
    )
    sim = make_simulation(cfg, shards, x_te, y_te)
    if scan:
        sim.run_window(0, scan)
    else:
        sim.run_round(0)
    _sync(sim)
    d0 = getattr(sim, "device_dispatches", 0)
    t0 = time.perf_counter()
    r = warm
    while r < warm + timed:
        if scan:
            sim.run_window(r, scan)
            r += scan
        else:
            sim.run_round(r)
            r += 1
    _sync(sim)
    dt = time.perf_counter() - t0
    dpr = (getattr(sim, "device_dispatches", 0) - d0) / timed
    return dt / timed, dpr


def _phase_attribution(
    cfg: SimConfig, shards, x_te, y_te, rounds: int
) -> dict:
    """Per-phase wall seconds for a short telemetry-instrumented run.

    The timed throughput rows stay telemetry-OFF (the < 2% overhead bar is
    measured on the disabled path); this extra pass turns the recorder's
    PhaseTimer on, drops the warm-up/compile round from the totals, and
    returns mean seconds per phase — the dispatch-level breakdown that
    attributes e.g. the int8 wire regression to its encode/decode stages.
    """
    sim = make_simulation(
        dataclasses.replace(cfg, telemetry=True, rounds=1 + rounds),
        shards, x_te, y_te,
    )
    sim.run_round(0)
    _sync(sim)
    sim.recorder.timer.totals.clear()  # compile lives in the warm-up round
    for r in range(1, 1 + rounds):
        sim.run_round(r)
    _sync(sim)
    return {
        name: ent["mean_s"] for name, ent in sim.recorder.timer.summary().items()
    }


def run(
    rounds: int = 4,
    agent_counts=(10, 32, 100),
    lossy_agent_counts=(10, 32),
    out_json: str | None = None,
    timestamp: str | None = None,
) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data(num_train=12000, num_test=800)
    rows: List[str] = []
    # the host stamp makes the persisted perf trajectory comparable across
    # machines; the timestamp comes from the runner so this stays clock-free
    results = {"host": host_metadata(timestamp)}
    variants = [("", PERFECT, agent_counts), ("_lossy", LOSSY, lossy_agent_counts)]
    for tag, cond, counts in variants:
        for n in counts:
            shards = iid_split(x_tr, y_tr, n, seed=0)
            cfg = SimConfig(
                num_agents=n, num_partitions=10, pi=2, rho=2,
                local_iters=2, batch_size=64, eval_agents=4, conditions=cond,
            )
            s_scalar, _ = _time_engine("scalar", shards, x_te, y_te, cfg, rounds)
            s_vec, d_vec = _time_engine("vectorized", shards, x_te, y_te, cfg, rounds)
            s_scan, d_scan = _time_engine(
                "vectorized", shards, x_te, y_te, cfg, rounds, scan=SCAN_W
            )
            speedup = s_scalar / s_vec
            scan_speedup = s_vec / s_scan
            results[f"n{n}{tag}"] = {
                "scalar_rounds_per_s": 1.0 / s_scalar,
                "vectorized_rounds_per_s": 1.0 / s_vec,
                "scanned_rounds_per_s": 1.0 / s_scan,
                "speedup": speedup,
                "scan_speedup_vs_vectorized": scan_speedup,
                "scan_rounds": SCAN_W,
                "vectorized_dispatches_per_round": d_vec,
                "scanned_dispatches_per_round": d_scan,
            }
            rows.append(
                csv_row(
                    f"rounds_scalar{tag}_n{n}",
                    s_scalar * 1e6,
                    f"rounds_per_s={1/s_scalar:.2f};agent_rounds_per_s={n/s_scalar:.1f}",
                )
            )
            rows.append(
                csv_row(
                    f"rounds_vectorized{tag}_n{n}",
                    s_vec * 1e6,
                    f"rounds_per_s={1/s_vec:.2f};agent_rounds_per_s={n/s_vec:.1f};"
                    f"speedup_vs_scalar={speedup:.1f}x;dispatches_per_round={d_vec:.2f}",
                )
            )
            rows.append(
                csv_row(
                    f"rounds_scan{SCAN_W}{tag}_n{n}",
                    s_scan * 1e6,
                    f"rounds_per_s={1/s_scan:.2f};agent_rounds_per_s={n/s_scan:.1f};"
                    f"speedup_vs_vectorized={scan_speedup:.2f}x;"
                    f"dispatches_per_round={d_scan:.3f}",
                )
            )
    # quantized wire plane: the same LOSSY round loop with f32 vs int8
    # transport — bytes_per_round is what the codec removes from the wire,
    # seconds_per_round what the quantize/fused-dequantize stages add
    n = lossy_agent_counts[0]
    shards = iid_split(x_tr, y_tr, n, seed=0)
    wire_stats = {}
    for wd in ("f32", "int8"):
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2,
            local_iters=2, batch_size=64, eval_agents=4,
            conditions=LOSSY, wire_dtype=wd,
            engine="vectorized", rounds=1 + rounds,
        )
        sim = make_simulation(cfg, shards, x_te, y_te)
        sim.run_round(0)  # jit warm-up outside the timed/byte window
        _sync(sim)
        b0 = sim._bytes_total
        t0 = time.perf_counter()
        for r in range(1, 1 + rounds):
            sim.run_round(r)
        _sync(sim)
        wire_stats[wd] = (
            (time.perf_counter() - t0) / rounds,
            (sim._bytes_total - b0) / rounds,
        )
    # dispatch-level attribution of the f32-vs-int8 gap: a second, short,
    # telemetry-instrumented pass per wire mode (the timed rows above stay
    # on the disabled path)
    phase_s = {}
    for wd in ("f32", "int8"):
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2,
            local_iters=2, batch_size=64, eval_agents=4,
            conditions=LOSSY, wire_dtype=wd, engine="vectorized",
        )
        phase_s[wd] = _phase_attribution(cfg, shards, x_te, y_te, rounds)
    ratio = wire_stats["f32"][1] / wire_stats["int8"][1]
    for wd, (s_w, bpr) in wire_stats.items():
        extra = f";bytes_ratio_vs_f32={ratio:.2f}x" if wd == "int8" else ""
        results[f"wire_{wd}_lossy_n{n}"] = {
            "rounds_per_s": 1.0 / s_w,
            "bytes_per_round": bpr,
            "phase_s": phase_s[wd],
            **({"bytes_ratio_vs_f32": ratio} if wd == "int8" else {}),
        }
        rows.append(
            csv_row(
                f"rounds_wire_{wd}_lossy_n{n}",
                s_w * 1e6,
                f"rounds_per_s={1/s_w:.2f};bytes_per_round={bpr:.0f}" + extra,
            )
        )

    # churn re-snapshot overhead: the scanned LOSSY loop with 3 membership
    # events over 30 rounds vs the identical run without churn. Event
    # rounds replay on the embedded scalar oracle and every span boundary
    # re-snapshots (and re-jits) the dense planes, so the delta between
    # the two runs, split across the events, is the per-event boundary
    # cost. Both runs time end-to-end including jit (the re-jit IS the
    # overhead being measured; the initial compile appears in both and
    # cancels in the difference).
    churn_rounds = 30
    churn = {5: [(1, "offline")], 14: [(1, "online")], 22: [(0, "crash")]}
    churn_stats = {}
    for label, sched in (("churn", churn), ("base", None)):
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2,
            local_iters=2, batch_size=64, eval_agents=4,
            conditions=LOSSY, churn=sched, engine="vectorized",
            scan_rounds=SCAN_W, rounds=churn_rounds,
        )
        sim = make_simulation(cfg, shards, x_te, y_te)
        t0 = time.perf_counter()
        sim.run()
        _sync(sim)
        churn_stats[label] = (
            time.perf_counter() - t0,
            sim.device_dispatches / churn_rounds,
        )
    t_churn, dpr_churn = churn_stats["churn"]
    t_base, dpr_base = churn_stats["base"]
    resnap_s = (t_churn - t_base) / len(churn)
    results[f"churn_scan{SCAN_W}_lossy_n{n}"] = {
        "rounds": churn_rounds,
        "events": len(churn),
        "seconds_per_round": t_churn / churn_rounds,
        "baseline_seconds_per_round": t_base / churn_rounds,
        "resnapshot_s_per_event": resnap_s,
        "dispatches_per_round": dpr_churn,
        "baseline_dispatches_per_round": dpr_base,
    }
    rows.append(
        csv_row(
            f"rounds_churn_scan{SCAN_W}_lossy_n{n}",
            (t_churn / churn_rounds) * 1e6,
            f"rounds_per_s={churn_rounds/t_churn:.2f};"
            f"resnapshot_s_per_event={resnap_s:.3f};"
            f"dispatches_per_round={dpr_churn:.3f}",
        )
    )

    # the static-analysis gate's own cost, kept visible in the perf
    # trajectory next to the numbers it guards
    repo = Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    analysis_findings = analyze_paths(
        [repo / "src", repo / "tests", repo / "benchmarks"]
    )
    analysis_s = time.perf_counter() - t0
    results["analysis_full_tree_s"] = analysis_s
    rows.append(
        csv_row(
            "analysis_full_tree",
            analysis_s * 1e6,
            f"findings={len(analysis_findings)}",
        )
    )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
