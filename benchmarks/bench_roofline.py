"""Roofline table from the dry-run results (EXPERIMENTS.md §Roofline source).

Reads dryrun_results.jsonl (produced by ``python -m repro.launch.dryrun``)
and emits one row per (arch x shape) on the single-pod mesh with the three
roofline terms, the dominant bottleneck, and the useful-FLOPs ratio. If the
file is missing, falls back to recomputing a small subset live (slow)."""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")


def run(path: str = RESULTS) -> List[str]:
    rows: List[str] = []
    if not os.path.exists(path):
        rows.append(csv_row("roofline_missing_dryrun", 0.0, "run repro.launch.dryrun first"))
        return rows
    with open(path) as f:
        cells = [json.loads(l) for l in f]
    for c in cells:
        if c.get("status") != "ok" or c.get("multi_pod"):
            continue
        name = f"roofline_{c['arch']}_{c['shape']}"
        step_ms = max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e3
        rows.append(
            csv_row(
                name,
                step_ms * 1e3,  # us per (roofline) step
                f"compute_ms={c['compute_s']*1e3:.2f};memory_ms={c['memory_s']*1e3:.2f};"
                f"collective_ms={c['collective_s']*1e3:.2f};bottleneck={c['bottleneck']};"
                f"useful={c['useful_ratio']:.3f};frac={c['roofline_fraction']:.4f}",
            )
        )
    n_multi = sum(1 for c in cells if c.get("status") == "ok" and c.get("multi_pod"))
    rows.append(csv_row("dryrun_multipod_cells_ok", 0.0, f"count={n_multi}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
