"""Paper Fig 2a/2b: model-training convergence, IPLS vs centralized FL for
10/25/50 agents over 40 rounds; the accuracy 'drop due to decentralisation'
must vanish (paper: < 1 per-mille after 40 iterations). An int8-wire overlay
tracks the same trajectory on the quantized delta plane — error feedback
must keep its final accuracy within 1e-3 of the f32 run."""
from __future__ import annotations

import dataclasses
import time
from typing import List

from benchmarks.common import csv_row, load_data, save_json
from repro.data import iid_split
from repro.fl import IPLSSimulation, SimConfig, make_simulation, run_centralized
from repro.telemetry import host_metadata


def run(
    rounds: int = 40,
    agent_counts=(10, 25, 50),
    out_json: str | None = None,
    timestamp: str | None = None,
) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data()
    rows: List[str] = []
    results = {"host": host_metadata(timestamp)}
    for n in agent_counts:
        shards = iid_split(x_tr, y_tr, n, seed=0)
        t0 = time.time()
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2, rounds=rounds,
            local_iters=10, batch_size=128, eval_agents=5,
        )
        hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
        t_ipls = time.time() - t0
        hist_c = run_centralized(shards, x_te, y_te, rounds=rounds, local_iters=10)
        # int8-wire overlay on the (equivalence-proven) vectorized engine;
        # telemetry stays on here — the recorder observes without perturbing
        # (bitwise-equal runs; tests/test_telemetry.py) and its PhaseTimer
        # gives the per-phase breakdown alongside the accuracy trace
        cfg_q = dataclasses.replace(
            cfg, wire_dtype="int8", engine="vectorized", telemetry=True
        )
        t0 = time.time()
        sim_q = make_simulation(cfg_q, shards, x_te, y_te)
        hist_q = sim_q.run()
        t_int8 = time.time() - t0
        acc_i = hist[-1]["acc_mean"]
        acc_c = hist_c[-1]["acc_mean"]
        acc_q = hist_q[-1]["acc_mean"]
        drop_permille = (acc_c - acc_i) / max(acc_c, 1e-9) * 1000.0
        int8_drop = acc_i - acc_q
        results[n] = {
            "ipls": [h["acc_mean"] for h in hist],
            "central": [h["acc_mean"] for h in hist_c],
            "ipls_int8": [h["acc_mean"] for h in hist_q],
            "final_drop_permille": drop_permille,
            "int8_drop_vs_f32": int8_drop,
            "int8_phase_s": {
                name: ent["mean_s"]
                for name, ent in sim_q.recorder.timer.summary().items()
            },
        }
        rows.append(
            csv_row(
                f"fig2_convergence_n{n}",
                t_ipls / rounds * 1e6,
                f"acc_ipls={acc_i:.4f};acc_central={acc_c:.4f};drop_permille={drop_permille:.2f}",
            )
        )
        rows.append(
            csv_row(
                f"fig2_convergence_int8_n{n}",
                t_int8 / rounds * 1e6,
                f"acc_int8={acc_q:.4f};drop_vs_f32={int8_drop:.5f}",
            )
        )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
