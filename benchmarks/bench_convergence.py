"""Paper Fig 2a/2b: model-training convergence, IPLS vs centralized FL for
10/25/50 agents over 40 rounds; the accuracy 'drop due to decentralisation'
must vanish (paper: < 1 per-mille after 40 iterations)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import csv_row, load_data, save_json
from repro.data import iid_split
from repro.fl import IPLSSimulation, SimConfig, run_centralized


def run(rounds: int = 40, agent_counts=(10, 25, 50), out_json: str | None = None) -> List[str]:
    x_tr, y_tr, x_te, y_te = load_data()
    rows: List[str] = []
    results = {}
    for n in agent_counts:
        shards = iid_split(x_tr, y_tr, n, seed=0)
        t0 = time.time()
        cfg = SimConfig(
            num_agents=n, num_partitions=10, pi=2, rho=2, rounds=rounds,
            local_iters=10, batch_size=128, eval_agents=5,
        )
        hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
        t_ipls = time.time() - t0
        hist_c = run_centralized(shards, x_te, y_te, rounds=rounds, local_iters=10)
        acc_i = hist[-1]["acc_mean"]
        acc_c = hist_c[-1]["acc_mean"]
        drop_permille = (acc_c - acc_i) / max(acc_c, 1e-9) * 1000.0
        results[n] = {
            "ipls": [h["acc_mean"] for h in hist],
            "central": [h["acc_mean"] for h in hist_c],
            "final_drop_permille": drop_permille,
        }
        rows.append(
            csv_row(
                f"fig2_convergence_n{n}",
                t_ipls / rounds * 1e6,
                f"acc_ipls={acc_i:.4f};acc_central={acc_c:.4f};drop_permille={drop_permille:.2f}",
            )
        )
    if out_json:
        save_json(out_json, results)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
