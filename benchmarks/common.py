"""Shared benchmark utilities: dataset, eval subsampling, CSV output."""
from __future__ import annotations

import json
from typing import List

from repro.data import synth_mnist

# evaluation uses a 2000-sample test subset and samples <=5 agents per round
# (full-set, all-agent eval would dominate single-core runtime without
# changing any relative conclusion)
EVAL_N = 2000


def load_data(num_train=60000, num_test=EVAL_N, seed=0):
    return synth_mnist(num_train=num_train, num_test=num_test, seed=seed)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def emit(rows: List[str]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


def save_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
