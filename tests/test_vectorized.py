"""Two-engine equivalence + partition-batched aggregation kernel.

The vectorized engine must reproduce the scalar engine's per-round dataflow
exactly under PERFECT conditions (same routing, same eps recursion, same
pre-merge reply caching); any residual difference is float noise from
batched vs per-agent device ops.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig, make_simulation
from repro.p2p.network import LOSSY

RNG = np.random.default_rng(3)


@pytest.fixture(scope="module")
def data():
    return synth_mnist(num_train=1500, num_test=300, seed=0)


def _run_both(data, **kw):
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(rounds=4, local_iters=3, **kw)
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_s = IPLSSimulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    sim_v = make_simulation(dataclasses.replace(cfg, engine="vectorized"), shards, x_te, y_te)
    hist_v = sim_v.run()
    return sim_s, hist_s, sim_v, hist_v


@pytest.mark.parametrize(
    "kw",
    [
        dict(num_agents=5, num_partitions=8, pi=2, rho=2),
        dict(num_agents=4, num_partitions=6, pi=2, rho=1),
        # more agents than partition slots: some agents own nothing
        dict(num_agents=10, num_partitions=6, pi=2, rho=2, eval_agents=3),
        dict(num_agents=6, num_partitions=5, pi=2, rho=3),
    ],
)
def test_engines_equivalent_under_perfect(data, kw):
    sim_s, hist_s, sim_v, hist_v = _run_both(data, **kw)
    for ms, mv in zip(hist_s, hist_v):
        assert ms["round"] == mv["round"] and ms["active"] == mv["active"]
        # identical routing => identical traffic, to the byte
        assert ms["bytes_total"] == mv["bytes_total"]
        np.testing.assert_allclose(ms["acc_mean"], mv["acc_mean"], atol=5e-3)
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(kw["num_agents"])])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=1e-4)


def test_vectorized_rejects_out_of_scope_configs(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 4, seed=0)
    lossy = SimConfig(num_agents=4, rounds=2, conditions=LOSSY, engine="vectorized")
    with pytest.raises(ValueError):
        make_simulation(lossy, shards, x_te, y_te)
    churny = SimConfig(num_agents=4, rounds=2, churn={1: [(3, "offline")]}, engine="vectorized")
    with pytest.raises(ValueError):
        make_simulation(churny, shards, x_te, y_te)
    with pytest.raises(ValueError):
        make_simulation(dataclasses.replace(lossy, engine="nope"), shards, x_te, y_te)


# ---- partition-batched Pallas kernel ----------------------------------------
@pytest.mark.parametrize("N", [256, 70001])  # 70001: padded tail in every tile
@pytest.mark.parametrize("R", [1, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_kernel_matches_per_partition_ref(N, R, dtype):
    from repro.kernels.ipls_aggregate.ops import aggregate_batched
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_ref

    K = 6
    w = jnp.asarray(RNG.standard_normal((K, N)), dtype)
    d = jnp.asarray(RNG.standard_normal((K, R, N)), dtype)
    m = jnp.asarray(RNG.integers(0, 2, (K, R)), jnp.float32)
    m = m.at[1].set(0.0)  # an r=0 partition must pass through untouched
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = aggregate_batched(w, d, m, eps)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    for k in range(K):
        ref_k = ipls_aggregate_ref(w[k], d[k], m[k], eps[k])
        np.testing.assert_allclose(
            np.asarray(got[k], np.float32), np.asarray(ref_k, np.float32), atol=tol, rtol=tol
        )
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(w[1]))


def test_batched_kernel_matches_batched_ref_unequal_sizes():
    """Zero-padded tails (partitions of unequal true size sharing one padded
    width) stay exactly zero through the kernel."""
    from repro.kernels.ipls_aggregate.ops import aggregate_batched
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_batched_ref

    K, R, N = 4, 3, 5000
    sizes = [5000, 3777, 1, 4096]
    w = np.zeros((K, N), np.float32)
    d = np.zeros((K, R, N), np.float32)
    for k, s in enumerate(sizes):
        w[k, :s] = RNG.standard_normal(s)
        d[k, :, :s] = RNG.standard_normal((R, s))
    m = jnp.ones((K, R), jnp.float32)
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = np.asarray(aggregate_batched(jnp.asarray(w), jnp.asarray(d), m, eps))
    ref = np.asarray(ipls_aggregate_batched_ref(jnp.asarray(w), jnp.asarray(d), m, eps))
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)
    for k, s in enumerate(sizes):
        assert np.all(got[k, s:] == 0.0)
