"""Two-engine equivalence + partition-batched aggregation kernel.

The vectorized engine must reproduce the scalar engine's per-round dataflow
exactly — under PERFECT conditions and under LOSSY ones, where both engines
read per-message fates from the same keyed counter-based stream (same
routing, same loss/delay decisions, same eps recursion, same reply caching);
any residual difference is float noise from batched vs per-agent device ops.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig, make_simulation
from repro.p2p.network import LOSSY, NetworkConditions

RNG = np.random.default_rng(3)


@pytest.fixture(scope="module")
def data():
    return synth_mnist(num_train=1500, num_test=300, seed=0)


def _run_both(data, **kw):
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(rounds=4, local_iters=3, **kw)
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_s = IPLSSimulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    sim_v = make_simulation(dataclasses.replace(cfg, engine="vectorized"), shards, x_te, y_te)
    hist_v = sim_v.run()
    return sim_s, hist_s, sim_v, hist_v


def _assert_equivalent(sim_s, hist_s, sim_v, hist_v, num_agents, atol_w=1e-4):
    for ms, mv in zip(hist_s, hist_v):
        assert ms["round"] == mv["round"] and ms["active"] == mv["active"]
        assert ms["bytes_total"] == mv["bytes_total"]
        np.testing.assert_allclose(ms["acc_mean"], mv["acc_mean"], atol=5e-3)
    # pubsub-mirroring counters stay live on both engine paths
    assert sim_s.net.pubsub.messages_sent == sim_v.messages_sent
    assert sim_s.net.pubsub.messages_dropped == sim_v.messages_dropped
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(num_agents)])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=atol_w)


@pytest.mark.parametrize(
    "kw",
    [
        dict(num_agents=5, num_partitions=8, pi=2, rho=2),
        dict(num_agents=4, num_partitions=6, pi=2, rho=1),
        # more agents than partition slots: some agents own nothing
        dict(num_agents=10, num_partitions=6, pi=2, rho=2, eval_agents=3),
        dict(num_agents=6, num_partitions=5, pi=2, rho=3),
    ],
)
def test_engines_equivalent_under_perfect(data, kw):
    sim_s, hist_s, sim_v, hist_v = _run_both(data, **kw)
    _assert_equivalent(sim_s, hist_s, sim_v, hist_v, kw["num_agents"])


# acceptance bar for the lossy-network vectorization: batched and scalar
# engines agree round-by-round across seeds — weights to float tolerance,
# messages_dropped / bytes_total exactly
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engines_equivalent_under_lossy(data, seed):
    sim_s, hist_s, sim_v, hist_v = _run_both(
        data, num_agents=5, num_partitions=8, pi=2, rho=2, conditions=LOSSY, seed=seed
    )
    _assert_equivalent(sim_s, hist_s, sim_v, hist_v, 5)
    assert sim_s.net.pubsub.messages_sent == sim_v.messages_sent
    assert sim_s.net.pubsub.messages_dropped == sim_v.messages_dropped
    assert sim_v.messages_dropped > 0  # losses actually happened


@pytest.mark.parametrize(
    "kw",
    [
        # rho=1: every loss is unrecoverable for the round; delayed updates
        # pile onto the single holder next round
        dict(num_agents=4, num_partitions=6, pi=2, rho=1, seed=5),
        # rho=3 exercises the replica-consensus masks + version filtering
        dict(num_agents=6, num_partitions=5, pi=2, rho=3, seed=6),
        # loss-only and delay-only corners of NetworkConditions
        dict(num_agents=4, num_partitions=6, pi=2, rho=2, seed=7,
             conditions=NetworkConditions(loss_prob=0.4)),
        dict(num_agents=4, num_partitions=6, pi=2, rho=2, seed=8,
             conditions=NetworkConditions(delay_prob=0.5, max_delay_rounds=2)),
        # delays longer than one round: deeper delta ring buffer
        dict(num_agents=4, num_partitions=6, pi=2, rho=2, seed=9,
             conditions=NetworkConditions(loss_prob=0.2, delay_prob=0.5, max_delay_rounds=6)),
    ],
)
def test_engines_equivalent_lossy_corners(data, kw):
    kw.setdefault("conditions", LOSSY)
    sim_s, hist_s, sim_v, hist_v = _run_both(data, **kw)
    _assert_equivalent(sim_s, hist_s, sim_v, hist_v, kw["num_agents"])
    assert sim_s.net.pubsub.messages_dropped == sim_v.messages_dropped


def test_lossy_kernel_path_matches_scalar(data):
    """The partition-batched Pallas kernel path (interpret mode off-TPU)
    aggregates the ring-buffered delta windows identically."""
    from repro.fl.vectorized import VectorizedIPLSSimulation

    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(
        num_agents=4, num_partitions=6, pi=2, rho=2, rounds=3,
        local_iters=2, conditions=LOSSY, seed=0,
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_s = IPLSSimulation(cfg, shards, x_te, y_te)
    sim_s.run()
    sim_v = VectorizedIPLSSimulation(cfg, shards, x_te, y_te, use_kernel=True)
    sim_v.run()
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(cfg.num_agents)])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=1e-4)
    assert sim_s.net.pubsub.total_bytes() == sim_v._bytes_total


def test_vectorized_rejects_out_of_scope_configs(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 4, seed=0)
    # lossy conditions are IN scope since the mask-stream path
    lossy = SimConfig(num_agents=4, rounds=2, conditions=LOSSY, engine="vectorized")
    sim = make_simulation(lossy, shards, x_te, y_te)
    assert sim._lossy
    # churn is IN scope since the event-boundary re-snapshot path
    churny = SimConfig(num_agents=4, rounds=2, churn={1: [(3, "offline")]}, engine="vectorized")
    sim = make_simulation(churny, shards, x_te, y_te)
    assert sim._lossy and sim._replay == [1]
    with pytest.raises(ValueError):
        make_simulation(dataclasses.replace(lossy, engine="nope"), shards, x_te, y_te)


# ---- partition-batched Pallas kernel ----------------------------------------
@pytest.mark.parametrize("N", [256, 70001])  # 70001: padded tail in every tile
@pytest.mark.parametrize("R", [1, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_kernel_matches_per_partition_ref(N, R, dtype):
    from repro.kernels.ipls_aggregate.ops import aggregate_batched
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_ref

    K = 6
    w = jnp.asarray(RNG.standard_normal((K, N)), dtype)
    d = jnp.asarray(RNG.standard_normal((K, R, N)), dtype)
    m = jnp.asarray(RNG.integers(0, 2, (K, R)), jnp.float32)
    m = m.at[1].set(0.0)  # an r=0 partition must pass through untouched
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = aggregate_batched(w, d, m, eps)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    for k in range(K):
        ref_k = ipls_aggregate_ref(w[k], d[k], m[k], eps[k])
        np.testing.assert_allclose(
            np.asarray(got[k], np.float32), np.asarray(ref_k, np.float32), atol=tol, rtol=tol
        )
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(w[1]))


def test_batched_kernel_matches_batched_ref_unequal_sizes():
    """Zero-padded tails (partitions of unequal true size sharing one padded
    width) stay exactly zero through the kernel."""
    from repro.kernels.ipls_aggregate.ops import aggregate_batched
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_batched_ref

    K, R, N = 4, 3, 5000
    sizes = [5000, 3777, 1, 4096]
    w = np.zeros((K, N), np.float32)
    d = np.zeros((K, R, N), np.float32)
    for k, s in enumerate(sizes):
        w[k, :s] = RNG.standard_normal(s)
        d[k, :, :s] = RNG.standard_normal((R, s))
    m = jnp.ones((K, R), jnp.float32)
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = np.asarray(aggregate_batched(jnp.asarray(w), jnp.asarray(d), m, eps))
    ref = np.asarray(ipls_aggregate_batched_ref(jnp.asarray(w), jnp.asarray(d), m, eps))
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)
    for k, s in enumerate(sizes):
        assert np.all(got[k, s:] == 0.0)


# ---- churn: event-boundary re-snapshot --------------------------------------
CHURN_ALL_ACTIONS = {
    1: [(2, "offline")],
    3: [(4, "leave"), (2, "online")],
    4: [(5, "join")],
    6: [(1, "crash")],
}


@pytest.mark.parametrize("scan", [0, 3])
@pytest.mark.parametrize("wire_dtype", ["f32", "int8"])
def test_churn_matches_scalar_all_actions(data, scan, wire_dtype):
    """All five membership actions on the vectorized engine (round-at-a-time
    and lax.scan-windowed): event rounds replay on the embedded scalar
    oracle, fused spans re-snapshot at each boundary, and the result matches
    the scalar engine exactly — weights, traffic counters, and the telemetry
    stream byte-for-byte."""
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(
        num_agents=5, num_partitions=6, pi=2, rho=2, rounds=8,
        local_iters=2, conditions=LOSSY, seed=0, churn=CHURN_ALL_ACTIONS,
        telemetry=True, memory=True, wire_dtype=wire_dtype,
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_s = make_simulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    sim_v = make_simulation(
        dataclasses.replace(cfg, engine="vectorized", scan_rounds=scan),
        shards, x_te, y_te,
    )
    hist_v = sim_v.run()
    for ms, mv in zip(hist_s, hist_v):
        assert ms["round"] == mv["round"] and ms["active"] == mv["active"]
        assert ms["bytes_total"] == mv["bytes_total"]
    ps = sim_s.net.pubsub
    assert ps.messages_sent == sim_v.messages_sent
    assert ps.messages_dropped == sim_v.messages_dropped
    ids = [a for a, ag in sim_s.agents.items() if ag.live]
    assert ids == sim_v.agent_ids()
    w_s = np.stack([sim_s.agents[a].load_model() for a in ids])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=3e-8)
    assert sim_s.recorder.jsonl_lines()[1:] == sim_v.recorder.jsonl_lines()[1:]
    if scan:
        # windows split only at the 4 event rounds: far fewer dispatches
        # than one (or more) per round
        assert sim_v.device_dispatches < cfg.rounds


def test_churn_rho1_crash_reassignment_matches(data):
    """rho=1 crash orphans partitions; the re-snapshot must pick up the
    table's reassignment and the zero/cache-seeded holder states."""
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(
        num_agents=4, num_partitions=8, pi=2, rho=1, rounds=6,
        local_iters=2, conditions=LOSSY, seed=2, churn={2: [(1, "crash")]},
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=2)
    sim_s = IPLSSimulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    sim_v = make_simulation(
        dataclasses.replace(cfg, engine="vectorized"), shards, x_te, y_te
    )
    hist_v = sim_v.run()
    for ms, mv in zip(hist_s, hist_v):
        assert ms["round"] == mv["round"] and ms["active"] == mv["active"]
        assert ms["bytes_total"] == mv["bytes_total"]
    assert sim_s.net.pubsub.messages_sent == sim_v.messages_sent
    assert sim_s.net.pubsub.messages_dropped == sim_v.messages_dropped
    ids = [a for a, ag in sim_s.agents.items() if ag.live]
    w_s = np.stack([sim_s.agents[a].load_model() for a in ids])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=3e-8)
