"""Per-architecture smoke tests (reduced configs of the same family):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode consistency. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_config

RNG = np.random.default_rng(0)


def make_batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.asarray(RNG.integers(0, 256, (B, S)), jnp.int32)}
    if getattr(cfg, "mrope", False):
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (3, B, S)
        ).astype(jnp.int32)
    if cfg.name.startswith("whisper"):
        batch["enc_embeds"] = jnp.asarray(
            RNG.standard_normal((B, S, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_no_nans(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(0)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    per_ex, aux = jax.jit(model.loss)(params, batch)
    assert per_ex.shape == (B,)
    a = np.asarray(per_ex, np.float32)
    assert not np.any(np.isnan(a)) and np.all(a > 0)
    # one full gradient step
    grads = jax.grad(lambda p: model.loss(p, batch)[0].mean())(params)
    gn = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32)))) for l in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(0)
    B, S, CL = 2, 16, 32
    batch = make_batch(cfg, B, S)
    batch["cache_len"] = CL
    logits, cache = model.prefill(params, batch)
    assert logits.shape[:2] == (B, 1)
    tok = jnp.asarray(RNG.integers(0, 256, (B, 1)), jnp.int32)
    logits2, cache2 = model.decode_step(
        params, cache, {"token": tok, "pos": jnp.asarray(S, jnp.int32)}
    )
    a = np.asarray(logits2, np.float32)
    assert not np.any(np.isnan(a))
    # reference: prefill of the extended prompt
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    if "positions3" in batch2:
        batch2["positions3"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None, :], (3, B, S + 1)
        ).astype(jnp.int32)
    ref, _ = model.prefill(params, batch2)
    err = float(jnp.max(jnp.abs(jnp.asarray(ref, jnp.float32) - a)))
    # MoE archs differ slightly: capacity drop patterns change with T
    tol = 0.5 if any(k in arch for k in ("moe", "deepseek", "zamba")) else 1e-2
    assert err < tol, (arch, err)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_match_shapes(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    shapes = model.param_shapes()
    axes = model.axes()
    flat_s = jax.tree.leaves(shapes)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_s) == len(flat_a)
    for s, a in zip(flat_s, flat_a):
        assert len(s.shape) == len(a), (s.shape, a)


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    c = get_config("gemma3-1b")
    assert c.vocab == 262144 and c.d_model == 1152 and c.n_layers == 52  # 26 attn + 26 mlp
    c = get_config("minitron-4b")
    assert c.d_model == 3072 and c.vocab == 256000
    c = get_config("qwen2-vl-72b")
    assert c.d_model == 8192 and c.vocab == 152064 and c.n_layers == 160  # 80 attn + 80 mlp
    c = get_config("deepseek-v2-lite-16b")
    assert c.vocab == 102400
    c = get_config("rwkv6-7b")
    assert c.d_model == 4096 and c.vocab == 65536
    c = get_config("whisper-base")
    assert c.d_model == 512 and c.vocab == 51865


def test_param_counts_in_expected_range():
    """Sanity: full configs land near their nameplate sizes."""
    expect = {
        "gemma3-1b": (0.9e9, 1.3e9),
        "minitron-4b": (3.6e9, 4.6e9),
        "phi4-mini-3.8b": (3.4e9, 4.3e9),
        "internlm2-1.8b": (1.6e9, 2.2e9),
        "granite-moe-3b-a800m": (2.8e9, 3.8e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "qwen2-vl-72b": (68e9, 76e9),
        "zamba2-1.2b": (0.9e9, 1.5e9),
        "whisper-base": (0.05e9, 0.12e9),
        "rwkv6-7b": (6.5e9, 8.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).num_params()
        assert lo < n < hi, (arch, n)
