"""JX05 fire: lax.cond branches return pytrees of different arity."""
import jax


def step(pred, x):
    return jax.lax.cond(pred, lambda: (x, x), lambda: (x,))
