"""PL04 fire: double-buffered f32 blocks of 8 MiB each blow the 16 MiB
VMEM budget (2 x 8 in + 2 x 8 out = 32 MiB)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def run(x):
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((2048, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2048, 1024), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((2048, 1024), jnp.float32),
    )(x)
