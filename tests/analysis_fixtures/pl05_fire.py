"""PL05 fire: the output block is revisited across the reduction axis j
(its index_map ignores j) but the kernel accumulates without @pl.when."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def acc_kernel(x_ref, o_ref):
    o_ref[...] += x_ref[...]


def run(x):
    return pl.pallas_call(
        acc_kernel,
        grid=(2, 4),
        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
    )(x)
