"""Suppression fixture: each violation carries a reasoned noqa — the file
must analyze clean, proving same-line and preceding-comment placement."""
import jax


@jax.jit
def f(x):
    if x > 0:  # repro: noqa[JX02] fixture: demonstrates same-line suppression
        return x
    # repro: noqa[JX01] fixture: demonstrates preceding-comment suppression
    return int(x) * x
