"""PR01 fire: fate drawn with a partial key — round and part are missing,
so every message this agent sends shares one fate."""
CH_UPDATE = 1


def deliver(fates, agent):
    delivered, delay = fates.draw(CH_UPDATE, agent)
    return delivered, delay
