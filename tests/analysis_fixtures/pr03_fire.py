"""PR03 fire: wire bytes computed as element-count times a hardcoded f32
width instead of the payload's own dtype/size."""


def sync_segment(net, topic, seg, sizes, k, peers):
    # element count * literal width at a publish sink
    net.publish(topic, 0, seg, nbytes=seg.size * 4)
    # and the same pattern feeding a byte counter
    total_bytes = 0
    total_bytes += int(sizes[k] * 4 * len(peers))
    return total_bytes
