"""JX02 fire: Python if on a traced value inside a jitted function."""
import jax


@jax.jit
def relu_wrong(x):
    if x > 0:
        return x
    return 0.0 * x
