"""PL03 fire: float32 block with a 64-wide lane dimension (native is 128)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 64), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 64), jnp.float32),
    )(x)
