"""No-fire twin for the pallas pack: aligned tiles, covered grid, budget-
sized blocks, and the revisited-accumulator pattern done right (init /
accumulate under @pl.when guards)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run_copy(x):
    return pl.pallas_call(
        copy_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
    )(x)


def acc_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(pl.program_id(1) > 0)
    def _acc():
        o_ref[...] += x_ref[...]


def run_acc(x):
    return pl.pallas_call(
        acc_kernel,
        grid=(2, 4),
        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
    )(x)
