"""PR04 fire: telemetry emission sites that drift from the shared metric
schema — a misspelled/unknown finish_round key (which also makes the row
incomplete) and an on_channel call naming a channel no engine declares."""


def emit(recorder, rnd, n_active, row):
    # 'activ' is not a schema key, and 'active' is therefore missing
    recorder.finish_round(
        round=rnd,
        activ=n_active,
        contrib=row["contrib"],
        eps=row["eps"],
        delta_normsq=row["dn"],
        value_normsq=row["vn"],
        accs=row["accs"],
        bytes_total=row["b"],
        msgs_total=row["m"],
        drops_total=row["d"],
    )
    # 'gossip' is not in telemetry.schema.CHANNELS
    recorder.on_channel(rnd, "gossip", 3, 1200, 0)
