"""PR02 fire: a traffic-counter increment nobody declared in the
symmetry table."""


class RogueEngine:
    def __init__(self):
        self.messages_sent = 0

    def deliver(self, msg):
        self.messages_sent += 1
        return msg
