"""JX04 fire: scan body mutates its carry (dict update + item assignment)."""
import jax


def body(carry, x):
    carry.update(last=x)
    state = carry
    state["n"] += 1
    return carry, x


def run(xs):
    return jax.lax.scan(body, {"n": 0}, xs)
