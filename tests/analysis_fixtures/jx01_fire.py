"""JX01 fire: int() coercion of a traced argument under jit."""
import jax


@jax.jit
def f(x):
    return x + int(x)
