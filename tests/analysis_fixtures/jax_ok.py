"""No-fire twin for the jax pack: the same intents expressed with static
arguments, shape metadata, functional carries, and matched cond branches."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode",))
def f(x, mode):
    # branching on a static_argnames kwarg is resolved at trace time
    if mode == "double":
        return x * 2
    # branching on shape metadata is static
    if x.shape[0] > 1:
        return jnp.sum(x)
    return x


@jax.jit
def relu_right(x):
    return jnp.where(x > 0, x, 0.0)


@jax.jit
def coerce_static(x):
    # int() of a shape dimension is host-side arithmetic
    n = int(x.shape[0])
    return x * n


def body(carry, x):
    total, count = carry
    if x is None:  # identity checks are host-side
        return (total, count), 0.0
    return (total + x, count + 1), total


def run(xs):
    return jax.lax.scan(body, (0.0, 0), xs)


def step(pred, x):
    return jax.lax.cond(pred, lambda: (x, x), lambda: (x * 2, x))
