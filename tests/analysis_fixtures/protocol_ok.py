"""No-fire twin for the protocol pack: full fate keys, no undeclared
counter sites."""
CH_UPDATE = 1
CH_REPLICA = 4


def deliver(fates, rnd, agent, part, peer):
    de, dl = fates.draw(CH_UPDATE, rnd, agent, part)
    de2, dl2 = fates.draw_one(CH_REPLICA, rnd, agent, part, peer)
    de3, dl3 = fates.draw_window(CH_UPDATE, rnd, agent, part, peer=peer)
    return de and de2 and de3, dl + dl2 + dl3


class Engine:
    def __init__(self):
        # plain initialization is not an accounting site
        self.messages_sent = 0
        self.local_hits = 0

    def deliver(self, msg):
        # only the declared traffic counters are protocol state
        self.local_hits += 1
        return msg


def emit(recorder, rnd, n_active, row):
    # a schema-complete, keyword-only emission with schema channel names
    recorder.on_channel(rnd, "update", row["m"], row["b"], 0)
    recorder.finish_round(
        round=rnd,
        active=n_active,
        contrib=row["contrib"],
        eps=row["eps"],
        delta_normsq=row["dn"],
        value_normsq=row["vn"],
        accs=row["accs"],
        bytes_total=row["b"],
        msgs_total=row["m"],
        drops_total=row["d"],
    )


def account(net, topic, seg, n_need, shards):
    # dtype-derived wire bytes and header-sized constants are all fine
    net.publish(topic, 0, seg, nbytes=seg.nbytes)
    total_bytes = seg.nbytes * len(shards)  # width comes from the payload
    total_bytes += 16 * n_need  # fixed request header times a count
    header_bytes = 800 * 4  # pure constant math carries no element count
    return total_bytes + header_bytes
