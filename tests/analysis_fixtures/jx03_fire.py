"""JX03 fire: numpy.random inside traced code runs once at trace time."""
import jax
import numpy as np


@jax.jit
def noisy(x):
    return x + np.random.normal()
