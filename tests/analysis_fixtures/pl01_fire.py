"""PL01 fire: index_map arity does not match the grid rank."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
    )(x)
