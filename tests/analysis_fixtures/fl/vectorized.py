"""PR02 no-fire: this fixture path ends in ``fl/vectorized.py`` so the
declared-symmetry entries apply — counters bumped inside a declared
function are clean. Functions the table declares but this partial file
omits are skipped, not stale."""


class VectorizedEngine:
    def __init__(self):
        self.messages_sent = 0
        self.messages_dropped = 0
        self._bytes_total = 0

    def _run_round_lossy(self, ctl):
        self.messages_sent += ctl["msgs"]
        self.messages_dropped += ctl["drops"]
        self._bytes_total += ctl["nbytes"]
