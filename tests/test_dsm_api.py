"""The IPLS middleware protocol end to end on the simulated substrate:
Init / UpdateModel / LoadModel / Terminate, fetch warm-up, replica sync,
and the paper's traffic bound (per-agent bytes <= 2|M| per round)."""
import numpy as np

from repro.core.api import IPLSAgent, reset_registry
from repro.core.partition import PartitionSpec, PartitionTable
from repro.p2p.ipfs_sim import SimIPFS
from repro.p2p.network import PERFECT


def make_world(n_agents=3, n_parts=6, pi=2, rho=2, total=600):
    reset_registry()
    net = SimIPFS(PERFECT, seed=0)
    spec = PartitionSpec.even(total, n_parts)
    table = PartitionTable(n_parts, pi, rho)
    w0 = np.arange(total, dtype=np.float32)
    agents = {}
    for a in range(n_agents):
        ag = IPLSAgent(a, net, table, spec)
        ag.init(w0 if a == 0 else None)
        agents[a] = ag
    return net, spec, table, agents, w0


def fetch_cycle(net, agents, rnd=0):
    for a in agents.values():
        if a.live:
            a.request_missing(rnd)
    net.tick()
    for a in agents.values():
        if a.live:
            a.serve_fetches()
    net.tick()
    for a in agents.values():
        if a.live:
            a.receive_replies()


def round_cycle(net, agents, deltas, rnd=0):
    for aid, a in agents.items():
        if a.live:
            a.update_model(deltas[aid], rnd)
    net.tick()
    for a in agents.values():
        a.collect()
    for a in agents.values():
        a.aggregate()
    for a in agents.values():
        a.serve_replies()
        a.sync_replicas(rnd)
    net.tick()
    for a in agents.values():
        a.receive_replies()
        a.merge_replicas()


def test_init_and_load_model():
    net, spec, table, agents, w0 = make_world()
    fetch_cycle(net, agents)
    for a in agents.values():
        np.testing.assert_allclose(a.load_model(), w0, rtol=1e-6)


def test_update_model_applies_eps_weighted_mean():
    net, spec, table, agents, w0 = make_world(n_agents=2, n_parts=2, pi=2, rho=2, total=8)
    fetch_cycle(net, agents)
    delta = np.ones(8, np.float32)
    round_cycle(net, agents, {0: delta, 1: delta})
    # both agents hold both partitions (rho=2); each holder received its own
    # + possibly the peer's delta; eps starts at 1 => w decreases by exactly 1
    fetch_cycle(net, agents, rnd=1)
    for a in agents.values():
        w = a.load_model()
        np.testing.assert_allclose(w, w0 - 1.0, rtol=1e-5)


def test_terminate_hands_off_and_preserves_coverage():
    net, spec, table, agents, w0 = make_world(n_agents=3, n_parts=6, pi=2, rho=1)
    fetch_cycle(net, agents)
    held = table.partitions_of(2)
    agents[2].terminate()
    assert table.coverage()
    assert not agents[2].live
    # uploaded partitions landed in the content store
    assert len(net.store) >= len(held) > 0
    # remaining agents can still assemble the full model
    fetch_cycle(net, agents)
    for aid in (0, 1):
        w = agents[aid].load_model()
        assert w.shape == w0.shape


def test_crash_recovers_via_replicas():
    net, spec, table, agents, w0 = make_world(n_agents=3, n_parts=4, pi=4, rho=2)
    fetch_cycle(net, agents)
    agents[1].crash()
    assert table.coverage()
    fetch_cycle(net, agents)
    for aid in (0, 2):
        np.testing.assert_allclose(agents[aid].load_model(), w0, rtol=1e-6)


def test_traffic_bound_2M_per_round():
    """Paper §2.1: per-round update traffic per agent is < 2|M| floats."""
    net, spec, table, agents, w0 = make_world(n_agents=4, n_parts=8, pi=2, rho=2, total=800)
    fetch_cycle(net, agents)
    base_sent = dict(net.pubsub.bytes_sent)
    delta = np.ones(800, np.float32)
    round_cycle(net, agents, {a: delta for a in agents})
    M_bytes = 800 * 4
    for aid in agents:
        sent = net.pubsub.bytes_sent[aid] - base_sent.get(aid, 0)
        # sends: delta slices for non-owned partitions (< |M|) + replies to
        # requesters (< |M|) + replica sync (bounded by owned partitions)
        assert sent <= 2.5 * M_bytes, (aid, sent, M_bytes)
