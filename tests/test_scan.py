"""Multi-round fused scan engine correctness.

``SimConfig(scan_rounds=W)`` folds W rounds into a single ``lax.scan`` device
call. The fused path must be *W-invariant*: any window size (including
partial tail windows) produces bit-for-bit the same per-round history and
the same final weights as the unscanned vectorized engine — which in turn
matches the scalar pubsub oracle to float tolerance with exact traffic
counters. Eval can additionally be thinned to a cadence without perturbing
the training trajectory.
"""

import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import SimConfig, make_simulation
from repro.p2p.network import LOSSY, NetworkConditions

# scanned vs unscanned is the same arithmetic in a different dispatch
# grouping: only scheduling noise separates them (PR-2 observed ~3e-8)
ATOL_SCAN = 3e-7
# vectorized vs scalar re-associates batched reductions: PR-2 tolerance
ATOL_ORACLE = 1e-4


@pytest.fixture(scope="module")
def data():
    return synth_mnist(num_train=1500, num_test=300, seed=0)


def _run(data, engine, scan, rounds=8, cadence=1, **kw):
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(
        rounds=rounds, local_iters=3, engine=engine, scan_rounds=scan,
        eval_cadence=cadence, **kw,
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim = make_simulation(cfg, shards, x_te, y_te)
    hist = sim.run()
    return sim, hist


def _assert_same_vectorized(sim_a, hist_a, sim_b, hist_b):
    """Two vectorized runs (different window sizes) must agree to float noise
    on weights/accs and exactly on every traffic counter."""
    np.testing.assert_allclose(
        sim_a.agent_weights(), sim_b.agent_weights(), atol=ATOL_SCAN
    )
    assert len(hist_a) == len(hist_b)
    for ma, mb in zip(hist_a, hist_b):
        assert ma["round"] == mb["round"] and ma["active"] == mb["active"]
        assert ma["bytes_total"] == mb["bytes_total"]
        np.testing.assert_allclose(ma["acc_mean"], mb["acc_mean"], atol=ATOL_SCAN)
    assert sim_a.messages_sent == sim_b.messages_sent
    assert sim_a.messages_dropped == sim_b.messages_dropped


NETS = [
    pytest.param({}, id="perfect"),
    pytest.param(dict(conditions=LOSSY, seed=1), id="lossy"),
]


@pytest.mark.parametrize("net", NETS)
def test_scan_w_invariance(data, net):
    """unscanned == scan_rounds=1 == scan_rounds=4: identical weights,
    metrics, and counters round-by-round (W only regroups dispatches)."""
    kw = dict(num_agents=5, num_partitions=8, pi=2, rho=2, **net)
    sim_u, hist_u = _run(data, "vectorized", 0, **kw)
    for W in (1, 4):
        sim_w, hist_w = _run(data, "vectorized", W, **kw)
        _assert_same_vectorized(sim_u, hist_u, sim_w, hist_w)


@pytest.mark.parametrize("net", NETS)
def test_scan8_matches_scalar_oracle(data, net):
    """Acceptance bar: scan_rounds=8 vs the scalar pubsub oracle — weights
    within PR-2 tolerance, bytes/messages/drops exactly equal per round,
    and the whole 8-round run is a single device dispatch."""
    kw = dict(num_agents=5, num_partitions=8, pi=2, rho=2, **net)
    sim_s, hist_s = _run(data, "scalar", 0, **kw)
    sim_w, hist_w = _run(data, "vectorized", 8, **kw)
    for ms, mw in zip(hist_s, hist_w):
        assert ms["round"] == mw["round"] and ms["active"] == mw["active"]
        assert ms["bytes_total"] == mw["bytes_total"]
        np.testing.assert_allclose(ms["acc_mean"], mw["acc_mean"], atol=5e-3)
    assert sim_s.net.pubsub.messages_sent == sim_w.messages_sent
    assert sim_s.net.pubsub.messages_dropped == sim_w.messages_dropped
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(kw["num_agents"])])
    np.testing.assert_allclose(w_s, sim_w.agent_weights(), atol=ATOL_ORACLE)
    assert sim_w.device_dispatches == 1


@pytest.mark.parametrize("net", NETS)
def test_scan_partial_tail_window(data, net):
    """rounds not divisible by scan_rounds: the tail window is shorter and
    must still agree with the unscanned engine."""
    kw = dict(num_agents=4, num_partitions=6, pi=2, rho=2, rounds=7, **net)
    sim_u, hist_u = _run(data, "vectorized", 0, **kw)
    sim_w, hist_w = _run(data, "vectorized", 4, **kw)  # windows of 4 + 3
    _assert_same_vectorized(sim_u, hist_u, sim_w, hist_w)
    assert sim_w.device_dispatches == 2


def test_scan_deep_delay_ring(data):
    """Delays spanning multiple rounds exercise the bounded-depth dense
    queues (depth Lu+1) inside the window control plane."""
    cond = NetworkConditions(loss_prob=0.2, delay_prob=0.5, max_delay_rounds=6)
    kw = dict(num_agents=4, num_partitions=6, pi=2, rho=2, conditions=cond, seed=9)
    sim_s, _ = _run(data, "scalar", 0, **kw)
    sim_u, hist_u = _run(data, "vectorized", 0, **kw)
    sim_w, hist_w = _run(data, "vectorized", 3, **kw)
    _assert_same_vectorized(sim_u, hist_u, sim_w, hist_w)
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(4)])
    np.testing.assert_allclose(w_s, sim_w.agent_weights(), atol=ATOL_ORACLE)
    assert sim_s.net.pubsub.messages_dropped == sim_w.messages_dropped


@pytest.mark.parametrize("net", NETS)
def test_eval_cadence_thins_eval_without_perturbing_training(data, net):
    """eval_cadence=3 evaluates every 3rd round + the final round; skipped
    rounds reuse the last computed accuracy. The weight trajectory and all
    traffic counters are untouched."""
    kw = dict(num_agents=5, num_partitions=8, pi=2, rho=2, **net)
    sim_u, hist_u = _run(data, "vectorized", 0, **kw)
    sim_c, hist_c = _run(data, "vectorized", 4, cadence=3, **kw)
    np.testing.assert_allclose(
        sim_u.agent_weights(), sim_c.agent_weights(), atol=ATOL_SCAN
    )
    assert len(hist_u) == len(hist_c)
    for mu, mc in zip(hist_u, hist_c):
        assert mu["bytes_total"] == mc["bytes_total"]
        r = mu["round"]
        if (r + 1) % 3 == 0 or r == 7:
            np.testing.assert_allclose(mu["acc_mean"], mc["acc_mean"], atol=ATOL_SCAN)
        assert np.isfinite(mc["acc_mean"])  # skipped rounds carry last eval


def test_scan_rounds_rejected_for_negative():
    cfg = SimConfig(num_agents=4, rounds=2, engine="vectorized", scan_rounds=-1)
    x = np.zeros((40, 784), np.float32)
    y = np.zeros((40,), np.int64)
    shards = iid_split(x, y, 4, seed=0)
    with pytest.raises(ValueError):
        make_simulation(cfg, shards, x[:8], y[:8])


def test_scalar_engine_ignores_scan_rounds(data):
    """scan_rounds is a vectorized-engine knob; the scalar oracle ignores it
    so configs can be shared across engines."""
    sim_a, hist_a = _run(data, "scalar", 0, rounds=3, num_agents=4)
    sim_b, hist_b = _run(data, "scalar", 4, rounds=3, num_agents=4)
    w_a = np.stack([sim_a.agents[a].load_model() for a in range(4)])
    w_b = np.stack([sim_b.agents[a].load_model() for a in range(4)])
    np.testing.assert_array_equal(w_a, w_b)
    assert [m["bytes_total"] for m in hist_a] == [m["bytes_total"] for m in hist_b]
