"""Aggregation math: eps updates, masked mean, staleness decay."""
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    aggregate_partition,
    apply_staleness_decay,
    init_eps,
    masked_mean,
    replica_consensus,
    update_eps,
)


def test_eps_update_rule():
    st = init_eps(alpha=0.5)
    st = update_eps(st, jnp.asarray(4.0))
    # eps = 0.5*1 + 0.5*(1/4)
    assert np.isclose(float(st.eps), 0.625)
    st = update_eps(st, jnp.asarray(2.0))
    assert np.isclose(float(st.eps), 0.5 * 0.625 + 0.5 * 0.5)


def test_eps_unchanged_when_no_contributors():
    st = init_eps(alpha=0.3)
    st2 = update_eps(st, jnp.asarray(0.0))
    assert float(st2.eps) == float(st.eps)


def test_masked_mean_matches_numpy():
    rng = np.random.default_rng(0)
    d = rng.standard_normal((5, 7)).astype(np.float32)
    m = np.array([1, 0, 1, 1, 0], np.float32)
    got = masked_mean(jnp.asarray(d), jnp.asarray(m))
    want = d[m.astype(bool)].mean(axis=0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_masked_mean_empty_is_zero():
    d = jnp.ones((3, 4))
    m = jnp.zeros((3,))
    assert float(jnp.max(jnp.abs(masked_mean(d, m)))) == 0.0


def test_aggregate_partition_applies_eps():
    w = jnp.ones((8,))
    deltas = jnp.ones((2, 8)) * 2.0
    mask = jnp.ones((2,))
    st = init_eps(alpha=0.5)
    new_w, st2 = aggregate_partition(w, deltas, mask, st)
    np.testing.assert_allclose(np.asarray(new_w), 1.0 - 1.0 * 2.0)  # eps=1 first round
    assert np.isclose(float(st2.eps), 0.75)  # 0.5 + 0.5/2


def test_replica_consensus_mean():
    vals = jnp.stack([jnp.zeros(4), jnp.ones(4) * 2])
    np.testing.assert_allclose(np.asarray(replica_consensus(vals)), 1.0)


def test_staleness_decay():
    d = jnp.ones((4,))
    out = apply_staleness_decay(d, jnp.asarray(2), beta=0.5)
    np.testing.assert_allclose(np.asarray(out), 0.25)
