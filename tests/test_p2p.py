"""IPFS-substitute substrate: store, pub/sub, loss/delay, determinism."""
import numpy as np

from repro.p2p.ipfs_sim import ContentStore, PubSub, SimIPFS
from repro.p2p.network import LOSSY, PERFECT, NetworkConditions


def test_content_store_roundtrip():
    s = ContentStore()
    cid = s.add(b"hello ipls")
    assert s.has(cid)
    assert s.cat(cid) == b"hello ipls"
    assert cid == s.add(b"hello ipls")  # content-addressed: same CID


def test_pubsub_delivery():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1)
    ps.subscribe("t", 2)
    ps.publish("t", sender=1, payload="x", nbytes=10)
    ps.tick()
    msgs = ps.drain(2)
    assert len(msgs) == 1 and msgs[0].payload == "x"
    assert ps.drain(1) == []  # no self-delivery


def test_directed_send():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2); ps.subscribe("t", 3)
    ps.send("t", sender=1, recipient=3, payload="y", nbytes=4)
    ps.tick()
    assert ps.drain(2) == []
    assert len(ps.drain(3)) == 1


def test_loss_and_delay_deterministic():
    cond = NetworkConditions(loss_prob=0.5, delay_prob=0.5, max_delay_rounds=2)
    outcomes = []
    for trial in range(2):
        ps = PubSub(cond, seed=42)
        ps.subscribe("t", 1); ps.subscribe("t", 2)
        delivered = 0
        for i in range(50):
            ps.publish("t", 1, i, 8)
            ps.tick()
            delivered += len(ps.drain(2))
        for _ in range(3):
            ps.tick()
            delivered += len(ps.drain(2))
        outcomes.append(delivered)
    assert outcomes[0] == outcomes[1]        # deterministic from seed
    assert 0 < outcomes[0] < 50              # losses happened


def test_offline_agents_receive_nothing():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2)
    ps.set_offline(2, True)
    ps.publish("t", 1, "z", 4)
    ps.tick()
    assert ps.drain(2) == []
    ps.set_offline(2, False)
    ps.publish("t", 1, "z2", 4)
    ps.tick()
    assert len(ps.drain(2)) == 1


def test_traffic_accounting():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2)
    ps.publish("t", 1, "a", nbytes=100)
    ps.tick(); ps.drain(2)
    assert ps.bytes_sent[1] == 100
    assert ps.bytes_recv[2] == 100
