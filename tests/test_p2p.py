"""IPFS-substitute substrate: store, pub/sub, loss/delay, determinism."""
import numpy as np

from repro.p2p.ipfs_sim import ContentStore, PubSub
from repro.p2p.network import PERFECT, NetworkConditions


def test_content_store_roundtrip():
    s = ContentStore()
    cid = s.add(b"hello ipls")
    assert s.has(cid)
    assert s.cat(cid) == b"hello ipls"
    assert cid == s.add(b"hello ipls")  # content-addressed: same CID


def test_pubsub_delivery():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1)
    ps.subscribe("t", 2)
    ps.publish("t", sender=1, payload="x", nbytes=10)
    ps.tick()
    msgs = ps.drain(2)
    assert len(msgs) == 1 and msgs[0].payload == "x"
    assert ps.drain(1) == []  # no self-delivery


def test_directed_send():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2); ps.subscribe("t", 3)
    ps.send("t", sender=1, recipient=3, payload="y", nbytes=4)
    ps.tick()
    assert ps.drain(2) == []
    assert len(ps.drain(3)) == 1


def test_loss_and_delay_deterministic():
    cond = NetworkConditions(loss_prob=0.5, delay_prob=0.5, max_delay_rounds=2)
    outcomes = []
    for trial in range(2):
        ps = PubSub(cond, seed=42)
        ps.subscribe("t", 1); ps.subscribe("t", 2)
        delivered = 0
        for i in range(50):
            ps.publish("t", 1, i, 8)
            ps.tick()
            delivered += len(ps.drain(2))
        for _ in range(3):
            ps.tick()
            delivered += len(ps.drain(2))
        outcomes.append(delivered)
    assert outcomes[0] == outcomes[1]        # deterministic from seed
    assert 0 < outcomes[0] < 50              # losses happened


def test_offline_agents_receive_nothing():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2)
    ps.set_offline(2, True)
    ps.publish("t", 1, "z", 4)
    ps.tick()
    assert ps.drain(2) == []
    ps.set_offline(2, False)
    ps.publish("t", 1, "z2", 4)
    ps.tick()
    assert len(ps.drain(2)) == 1


def test_traffic_accounting():
    ps = PubSub(PERFECT, seed=0)
    ps.subscribe("t", 1); ps.subscribe("t", 2)
    ps.publish("t", 1, "a", nbytes=100)
    ps.tick(); ps.drain(2)
    assert ps.bytes_sent[1] == 100
    assert ps.bytes_recv[2] == 100


def test_drain_prefix_is_prefix_not_substring():
    """drain(topic_prefix=...) must use startswith semantics: a topic
    embedding another topic's name mid-string must not be cross-drained."""
    ps = PubSub(PERFECT, seed=0)
    for topic in ("ipls/reply", "shadow/ipls/reply", "ipls/reply/sub"):
        ps.subscribe(topic, 2)
        ps.publish(topic, 1, topic, nbytes=4)
    ps.tick()
    got = ps.drain(2, "ipls/reply")
    assert sorted(m.topic for m in got) == ["ipls/reply", "ipls/reply/sub"]
    rest = ps.drain(2)
    assert [m.topic for m in rest] == ["shadow/ipls/reply"]


def test_sample_stream_keyed_determinism():
    """Counter-based fates are order-free: any subset of keys drawn in any
    order (or one at a time) reads identical values, and the distribution
    respects the loss/delay caps."""
    cond = NetworkConditions(loss_prob=0.3, delay_prob=0.4, max_delay_rounds=3)
    rounds, agents, parts = np.meshgrid(
        np.arange(5), np.arange(7), np.arange(4), indexing="ij"
    )
    de, dl = cond.sample_stream(123, 2, rounds, agents, parts)
    # scalar lookups in scrambled order agree elementwise
    rng = np.random.default_rng(0)
    for _ in range(50):
        i, j, k = rng.integers(5), rng.integers(7), rng.integers(4)
        de1, dl1 = cond.sample_stream(123, 2, int(rounds[i, j, k]), int(agents[i, j, k]), int(parts[i, j, k]))
        assert bool(de1) == de[i, j, k] and int(dl1) == dl[i, j, k]
    assert 0 < de.sum() < de.size            # losses happened
    assert dl.max() <= 3 and dl.min() == 0   # capped geometric
    assert np.all(dl[~de] == 0)
    # a different channel/seed decorrelates
    de2, _ = cond.sample_stream(123, 3, rounds, agents, parts)
    assert not np.array_equal(de, de2)
