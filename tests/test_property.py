"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import init_eps, masked_mean, update_eps
from repro.core.partition import PartitionSpec, PartitionTable


# ---- partition control plane ------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(2, 12),
    pi=st.integers(1, 6),
    rho=st.integers(1, 4),
    ops=st.lists(st.integers(0, 2), min_size=1, max_size=12),
    data=st.data(),
)
def test_partition_invariants_under_churn(k, pi, rho, ops, data):
    """Under any join/leave/fail sequence: validate() holds, coverage holds
    while agents remain, nobody exceeds rho except coverage-preserving
    handoff, and every agent holds <= K partitions."""
    t = PartitionTable(k, pi, rho)
    t.bootstrap(0)
    next_id = 1
    live = {0}
    for op in ops:
        if op == 0 or len(live) <= 1:  # join
            t.join(next_id)
            live.add(next_id)
            next_id += 1
        else:
            # repro: noqa[PR01] hypothesis strategy draw, not a fate stream
            victim = data.draw(st.sampled_from(sorted(live)))
            if op == 1:
                t.leave(victim)
            else:
                t.fail(victim)
            live.discard(victim)
        t.validate()
        if live:
            assert t.coverage()
        for a in list(live):
            assert 0 <= t.load(a) <= k


@settings(max_examples=30, deadline=None)
@given(total=st.integers(1, 10_000), k=st.integers(1, 64))
def test_partition_spec_even_properties(total, k):
    s = PartitionSpec.even(total, k)
    assert s.total == total
    assert len(s.sizes) == k
    assert max(s.sizes) - min(s.sizes) <= 1
    offs = s.offsets()
    for i in range(1, k):
        assert offs[i] == offs[i - 1] + s.sizes[i - 1]


# ---- aggregation math ----------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 8),
    n=st.integers(1, 65),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_mean_bounded_by_extremes(r, n, seed):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((r, n)).astype(np.float32)
    m = rng.integers(0, 2, r).astype(np.float32)
    out = np.asarray(masked_mean(jnp.asarray(d), jnp.asarray(m)))
    if m.sum() == 0:
        assert np.all(out == 0)
    else:
        sel = d[m.astype(bool)]
        assert np.all(out <= sel.max(axis=0) + 1e-5)
        assert np.all(out >= sel.min(axis=0) - 1e-5)


@settings(max_examples=30, deadline=None)
@given(
    alpha=st.floats(0.01, 0.99),
    rs=st.lists(st.integers(1, 50), min_size=1, max_size=40),
)
def test_eps_stays_in_unit_interval(alpha, rs):
    """eps is a convex combination of 1 and 1/r terms => always in (0, 1]."""
    stt = init_eps(alpha=alpha)
    for r in rs:
        stt = update_eps(stt, jnp.asarray(float(r)))
        e = float(stt.eps)
        assert 0.0 < e <= 1.0 + 1e-6


# ---- two-engine equivalence under imperfect connectivity -------------------
@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    rho=st.integers(1, 3),
    loss=st.floats(0.0, 0.5),
    delay=st.floats(0.0, 0.6),
)
def test_engines_agree_under_lossy_conditions(seed, rho, loss, delay):
    """Property: for any seed and loss/delay mix, the scalar oracle and the
    vectorized mask-stream engine agree round-by-round — weights to float
    tolerance, bytes_total / messages_sent / messages_dropped exactly."""
    import dataclasses

    from repro.data import iid_split, synth_mnist
    from repro.fl import IPLSSimulation, SimConfig, make_simulation
    from repro.p2p.network import NetworkConditions

    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=600, num_test=100, seed=0)
    cond = NetworkConditions(loss_prob=loss, delay_prob=delay, max_delay_rounds=2)
    cfg = SimConfig(
        num_agents=4, num_partitions=6, pi=2, rho=rho, rounds=3,
        local_iters=2, conditions=cond, seed=seed,
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_s = IPLSSimulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    sim_v = make_simulation(
        dataclasses.replace(cfg, engine="vectorized"), shards, x_te, y_te
    )
    hist_v = sim_v.run()
    for ms, mv in zip(hist_s, hist_v):
        assert ms["bytes_total"] == mv["bytes_total"]
        np.testing.assert_allclose(ms["acc_mean"], mv["acc_mean"], atol=5e-3)
    if sim_v._lossy:
        assert sim_s.net.pubsub.messages_sent == sim_v.messages_sent
        assert sim_s.net.pubsub.messages_dropped == sim_v.messages_dropped
    w_s = np.stack([sim_s.agents[a].load_model() for a in range(cfg.num_agents)])
    np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    window=st.integers(1, 5),
    rho=st.integers(1, 3),
    loss=st.floats(0.0, 0.5),
    delay=st.floats(0.0, 0.6),
)
def test_scan_windows_agree_with_unscanned(seed, window, rho, loss, delay):
    """Property: for any window size and loss/delay mix, the fused
    ``lax.scan`` engine reproduces the unscanned vectorized engine —
    weights to scheduling-noise tolerance (~3e-8 observed, PR-2 bar 1e-4),
    bytes_total / messages_sent / messages_dropped exactly."""
    import dataclasses

    from repro.data import iid_split, synth_mnist
    from repro.fl import SimConfig, make_simulation
    from repro.p2p.network import NetworkConditions

    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=600, num_test=100, seed=0)
    cond = NetworkConditions(loss_prob=loss, delay_prob=delay, max_delay_rounds=2)
    cfg = SimConfig(
        num_agents=4, num_partitions=6, pi=2, rho=rho, rounds=4,
        local_iters=2, conditions=cond, seed=seed, engine="vectorized",
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim_u = make_simulation(cfg, shards, x_te, y_te)
    hist_u = sim_u.run()
    sim_w = make_simulation(
        dataclasses.replace(cfg, scan_rounds=window), shards, x_te, y_te
    )
    hist_w = sim_w.run()
    for mu, mw in zip(hist_u, hist_w):
        assert mu["bytes_total"] == mw["bytes_total"]
        np.testing.assert_allclose(mu["acc_mean"], mw["acc_mean"], atol=1e-4)
    assert sim_u.messages_sent == sim_w.messages_sent
    assert sim_u.messages_dropped == sim_w.messages_dropped
    np.testing.assert_allclose(
        sim_u.agent_weights(), sim_w.agent_weights(), atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    seed=st.integers(0, 2**31 - 1),
    mag=st.integers(-30, 20),
)
def test_quantize_error_feedback_invariant(n, seed, mag):
    """Pow2 codec invariants, for any shape and magnitude: the residual
    reconstructs the input EXACTLY (every codec op is exact in f32), the
    scales are powers of two or zero, and the per-element error is bounded
    by one scale step (the clipped absmax element can use the full step)."""
    from repro.kernels.quantize.ref import dequantize_ref, quantize_ref

    rng = np.random.default_rng(seed)
    pad = (-n) % 1024
    x = jnp.asarray(
        rng.standard_normal(n + pad) * np.float64(2.0) ** mag, jnp.float32
    )
    e = jnp.zeros_like(x)
    q, s, ne = quantize_ref(x, e)
    deq = dequantize_ref(q, s)
    # exact reconstruction: deq + residual is bitwise the input
    np.testing.assert_array_equal(np.asarray(deq + ne), np.asarray(x))
    # scales are 0 (dead block) or exact powers of two
    s_np = np.asarray(s)
    nz = s_np[s_np > 0]
    assert np.all((nz.view(np.int32) & 0x007FFFFF) == 0)
    # per-element error within one quantization step of its block
    err_blocks = np.abs(np.asarray(ne)).reshape(-1, 1024)
    assert np.all(err_blocks <= s_np[:, None] + np.float32(1e-30))


# ---- engine equivalence under random churn ----------------------------------
_CHURN_DATA = None


def _churn_data():
    global _CHURN_DATA
    if _CHURN_DATA is None:
        from repro.data import synth_mnist

        _CHURN_DATA = synth_mnist(num_train=600, num_test=100, seed=0)
    return _CHURN_DATA


@settings(max_examples=3, deadline=None)
@given(
    rho=st.integers(1, 3),
    int8=st.booleans(),
    memory=st.booleans(),
    plan=st.lists(
        st.tuples(
            st.integers(1, 4),  # event round
            st.sampled_from(["offline", "online", "leave", "crash", "join"]),
        ),
        min_size=1,
        max_size=4,
    ),
)
def test_engines_equivalent_under_random_churn(rho, int8, memory, plan):
    """Any membership-event schedule (all five actions, memory on or off)
    keeps the scalar, vectorized and scanned engines equivalent under LOSSY
    conditions: weights within the established float tolerance, traffic
    counters exactly equal."""
    import dataclasses

    from repro.data import iid_split
    from repro.fl import SimConfig, make_simulation
    from repro.p2p.network import LOSSY

    num_agents = 4
    churn = {}
    for i, (rnd, action) in enumerate(plan):
        # joins use fresh ids; every other event targets a distinct
        # original agent, so events never conflict on one id
        aid = num_agents + i if action == "join" else i % num_agents
        churn.setdefault(rnd, []).append((aid, action))
    x_tr, y_tr, x_te, y_te = _churn_data()
    cfg = SimConfig(
        num_agents=num_agents, num_partitions=5, pi=2, rho=rho, rounds=6,
        local_iters=1, conditions=LOSSY, seed=0, churn=churn, memory=memory,
        wire_dtype="int8" if int8 else "f32",
    )
    shards = iid_split(x_tr, y_tr, num_agents, seed=0)
    sim_s = make_simulation(cfg, shards, x_te, y_te)
    hist_s = sim_s.run()
    ids = [a for a, ag in sim_s.agents.items() if ag.live]
    w_s = np.stack([sim_s.agents[a].load_model() for a in ids]) if ids else None
    ps = sim_s.net.pubsub
    for scan in (0, 3):
        sim_v = make_simulation(
            dataclasses.replace(cfg, engine="vectorized", scan_rounds=scan),
            shards, x_te, y_te,
        )
        hist_v = sim_v.run()
        for ms, mv in zip(hist_s, hist_v):
            assert ms["active"] == mv["active"]
            assert ms["bytes_total"] == mv["bytes_total"]
        assert ps.messages_sent == sim_v.messages_sent
        assert ps.messages_dropped == sim_v.messages_dropped
        if w_s is not None:
            np.testing.assert_allclose(w_s, sim_v.agent_weights(), atol=3e-8)
