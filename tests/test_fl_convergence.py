"""FL-level behaviour: IPLS converges and tracks centralized FedAvg
(the paper's Fig 2 claim, scaled down for CI speed)."""
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig, run_centralized, run_gossip
from repro.p2p.network import LOSSY


@pytest.fixture(scope="module")
def data():
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=3000, num_test=800, seed=0)
    return x_tr, y_tr, x_te, y_te


def test_ipls_converges_and_tracks_centralized(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 4, seed=0)
    cfg = SimConfig(num_agents=4, num_partitions=8, pi=2, rho=2, rounds=8, local_iters=5)
    hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
    hist_c = run_centralized(shards, x_te, y_te, rounds=8, local_iters=5)
    acc_ipls = hist[-1]["acc_mean"]
    acc_c = hist_c[-1]["acc_mean"]
    assert acc_ipls > 0.8, acc_ipls                      # it learns
    assert acc_ipls > hist[0]["acc_mean"] + 0.3          # it improves
    assert acc_c - acc_ipls < 0.1, (acc_c, acc_ipls)     # tracks centralized


def test_ipls_survives_lossy_network(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 4, seed=0)
    cfg = SimConfig(
        num_agents=4, num_partitions=8, pi=2, rho=2, rounds=8,
        local_iters=5, conditions=LOSSY,
    )
    hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
    assert hist[-1]["acc_mean"] > 0.6  # degraded but converging


def test_ipls_survives_churn(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 4, seed=0)
    churn = {2: [(3, "offline")], 5: [(3, "online")]}
    cfg = SimConfig(
        num_agents=4, num_partitions=8, pi=2, rho=2, rounds=8,
        local_iters=5, churn=churn, memory=True,
    )
    hist = IPLSSimulation(cfg, shards, x_te, y_te).run()
    assert hist[-1]["acc_mean"] > 0.75
    # the disconnected round ran with fewer active agents
    assert hist[2]["active"] == 3


def test_gossip_baseline_runs(data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, 3, seed=0)
    hist = run_gossip(shards, x_te, y_te, rounds=3, fanout=1, local_iters=3)
    assert hist[-1]["acc_mean"] > 0.3
    assert hist[-1]["bytes_total"] > 0


def test_ipls_traffic_scales_per_agent_constant(data):
    """Paper scalability claim: per-agent traffic per round is ~constant in
    the number of agents."""
    x_tr, y_tr, x_te, y_te = data
    per_agent = []
    for n in (3, 6):
        shards = iid_split(x_tr, y_tr, n, seed=0)
        cfg = SimConfig(num_agents=n, num_partitions=8, pi=2, rho=2, rounds=3, local_iters=2)
        sim = IPLSSimulation(cfg, shards, x_te, y_te)
        sim.run()
        per_agent.append(sim.net.pubsub.total_bytes() / n / 3)
    ratio = per_agent[1] / per_agent[0]
    assert ratio < 1.5, per_agent  # doubling agents does NOT double per-agent traffic
