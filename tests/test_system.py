"""End-to-end behaviour tests for the paper's system: the full IPLS protocol
training the paper's model on the simulated substrate, plus the datacenter
train-step built end-to-end through the launcher on the smoke mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig


def test_end_to_end_ipls_training():
    """Boot 3 agents, train the paper's MLP for 5 rounds over simulated
    IPFS, verify the assembled global model improved and every agent
    converged to (nearly) the same model."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=2000, num_test=500, seed=1)
    shards = iid_split(x_tr, y_tr, 3, seed=1)
    cfg = SimConfig(num_agents=3, num_partitions=6, pi=2, rho=2, rounds=5, local_iters=5)
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    hist = sim.run()
    assert hist[-1]["acc_mean"] > hist[0]["acc_mean"] + 0.2
    # agents agree: per-agent accuracy spread is small by the last round
    assert hist[-1]["acc_std"] < 0.08
    # traffic was metered
    assert sim.net.pubsub.total_bytes() > 0


def test_crash_with_rho1_partition_keeps_updating():
    """Regression: a crash with rho=1 orphans partitions; the table
    reassigns them but the data plane must seed the new holder with a
    PartitionState (from a replica, its own cache, or zeros) — otherwise
    every delta for the partition is dropped and it freezes at stale cache
    values for the rest of the run."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=1500, num_test=300, seed=2)
    shards = iid_split(x_tr, y_tr, 4, seed=2)
    cfg = SimConfig(
        num_agents=4, num_partitions=8, pi=2, rho=1, rounds=6,
        local_iters=3, churn={2: [(1, "crash")]},
    )
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    orphaned = sim.table.partitions_of(1)
    assert orphaned  # the victim actually held partitions
    for rnd in range(3):
        sim.run_round(rnd)
    # every partition has a holder with live data-plane state again
    versions = {}
    for k in range(cfg.num_partitions):
        holders = sim.table.holders_of(k)
        assert holders, f"partition {k} orphaned"
        h = holders[0]
        assert k in sim.agents[h].owned, f"holder {h} has no PartitionState for {k}"
        versions[k] = (h, sim.agents[h].owned[k].version)
    for rnd in range(3, cfg.rounds):
        sim.run_round(rnd)
    # the reassigned partitions kept aggregating after the crash
    for k in orphaned:
        h, v_before = versions[k]
        v_after = sim.agents[h].owned[k].version
        assert v_after > v_before, f"partition {k} froze after the crash"


def test_joined_agent_contributes_deltas():
    """Regression: a "join" churn action must hand the new agent a data
    shard — otherwise run_round skips its training forever and holders
    never see a delta from it."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=1500, num_test=300, seed=3)
    shards = iid_split(x_tr, y_tr, 3, seed=3)
    joiner = 7
    cfg = SimConfig(
        num_agents=3, num_partitions=6, pi=2, rho=2, rounds=5,
        local_iters=3, churn={2: [(joiner, "join")]},
    )
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    hist = sim.run()
    assert joiner in sim.trainers  # got a shard
    # its deltas went over the wire and holders replied with fresh values
    assert sim.net.pubsub.bytes_sent[joiner] > 0
    assert len(sim.agents[joiner].cache) + len(sim.agents[joiner].owned) > 0
    assert sim.net.pubsub.bytes_recv[joiner] > 0
    assert hist[-1]["active"] == 4
    # the joiner's replicas inherited the incumbents' version, so replica
    # consensus stays two-directional (equal versions every round after)
    for k in sim.table.partitions_of(joiner):
        versions = {sim.agents[h].owned[k].version for h in sim.table.holders_of(k)}
        assert len(versions) == 1, (k, versions)


def test_joiner_aliasing_live_shard_gets_free_shard():
    """Regression: the join fallback shard used to be
    ``agent_id % len(shards)``, which can hand a joiner a shard an active
    agent is already training on — double-counting that data in the
    average. With a shard freed by a crash, the joiner must take the free
    shard even when its id aliases a live agent's index."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=1500, num_test=300, seed=4)
    shards = iid_split(x_tr, y_tr, 4, seed=4)
    joiner = 4  # 4 % 4 == 0: aliases live agent 0's shard
    cfg = SimConfig(
        num_agents=4, num_partitions=6, pi=2, rho=2, rounds=5,
        local_iters=2, churn={1: [(1, "crash")], 2: [(joiner, "join")]},
    )
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    sim.run()
    # the crash freed shard 1; the joiner got it, not agent 0's shard 0
    assert sim._trainer_shard[joiner] == 1
    np.testing.assert_array_equal(sim.trainers[joiner].x, shards[1][0])
    # no live pair trains the same shard
    live = [a for a, ag in sim.agents.items() if ag.live]
    held = [sim._trainer_shard[a] for a in live if a in sim._trainer_shard]
    assert len(held) == len(set(held))


def test_same_round_churn_events_apply_in_class_order():
    """Same-round events apply departures -> joins -> offline/online
    regardless of their list order in cfg.churn (the SimConfig.churn
    contract), so conflicting pairs like crash+join of one id are
    deterministic: the join always wins and yields a fresh live agent."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=1500, num_test=300, seed=5)
    shards = iid_split(x_tr, y_tr, 4, seed=5)

    def run(events):
        cfg = SimConfig(
            num_agents=4, num_partitions=6, pi=2, rho=2, rounds=4,
            local_iters=2, churn={2: events},
        )
        sim = IPLSSimulation(cfg, shards, x_te, y_te)
        hist = sim.run()
        return sim, hist

    sim_a, hist_a = run([(1, "crash"), (1, "join")])
    sim_b, hist_b = run([(1, "join"), (1, "crash")])
    for sim in (sim_a, sim_b):
        assert sim.agents[1].live  # join applied after the crash
    assert [m["active"] for m in hist_a] == [m["active"] for m in hist_b]
    for a in sim_a.agents:
        np.testing.assert_array_equal(
            sim_a.agents[a].load_model(), sim_b.agents[a].load_model()
        )


def test_merge_replicas_discards_stale_versions():
    """A replica value published in an earlier round (delayed delivery)
    carries an older version and must not be mean-merged next to fresh
    values; same-or-newer versions merge as before."""
    from repro.core.api import IPLSAgent, REPLICA_TOPIC, reset_registry
    from repro.core.partition import PartitionSpec, PartitionTable
    from repro.p2p.ipfs_sim import SimIPFS

    reset_registry()
    net = SimIPFS()
    table = PartitionTable(2, 2, 2)
    spec = PartitionSpec.even(8, 2)
    a0 = IPLSAgent(0, net, table, spec)
    a0.init(np.zeros(8, np.float32))
    a1 = IPLSAgent(1, net, table, spec)
    a1.init()  # replicates both partitions (pi=2, rho=2)
    k = 0
    assert k in a0.owned and k in a1.owned
    a1.owned[k].version = 2
    v_before = a1.owned[k].value.copy()
    stale = np.full(spec.sizes[k], 9.0, np.float32)
    net.pubsub.publish(f"{REPLICA_TOPIC}/{k}", 0, (k, stale, 1), nbytes=16)
    net.tick()
    a1.merge_replicas()
    np.testing.assert_array_equal(a1.owned[k].value, v_before)  # stale: discarded
    net.pubsub.publish(f"{REPLICA_TOPIC}/{k}", 0, (k, stale, 2), nbytes=16)
    net.tick()
    a1.merge_replicas()
    np.testing.assert_allclose(a1.owned[k].value, 0.5 * (v_before + stale))


def test_end_to_end_datacenter_train_step():
    """Build the full launcher path (model -> shardings -> jit) on the
    1-device smoke mesh with a reduced arch, run 3 real steps, loss drops."""
    from repro.configs import get_config, build_model
    from repro.configs.registry import ShapeSpec
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.steps import build_train_step
    from repro.core.sharded import init_state, IplsStepConfig
    from repro.optim import sgd

    cfg = get_config("internlm2-1.8b", reduced=True)
    model = build_model(cfg)
    mesh = make_smoke_mesh()
    shape = ShapeSpec("smoke", seq_len=32, global_batch=4, kind="train")
    opt = sgd(0.5)
    built = build_train_step(
        model, mesh, shape,
        optimizer=opt,
        step_cfg=IplsStepConfig(grad_clip=1.0),
    )
    params = model.init(0)
    state = init_state(params, opt)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 256, (4, 32)), jnp.int32),
        "participation": jnp.ones((4,), jnp.float32),
    }
    step = jax.jit(built.fn, in_shardings=built.in_shardings, out_shardings=built.out_shardings)
    with built.mesh:
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(state.step) == 3
    assert not np.isnan(losses[-1])


def test_elastic_restart_from_checkpoint(tmp_path):
    """Fault tolerance at the datacenter layer: kill after step 2, restore,
    continue — state matches an uninterrupted run."""
    from repro.checkpoint import CheckpointManager
    from repro.core.sharded import init_state, make_train_step, IplsStepConfig, IplsTrainState
    from repro.optim import sgd

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["y"]), axis=-1), {}

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    batch = {
        "x": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
    }
    opt = sgd(0.1)
    step = jax.jit(make_train_step(loss_fn, opt, IplsStepConfig(use_eps=False, grad_clip=None)))

    # uninterrupted
    s = init_state(params, opt)
    for _ in range(4):
        s, _ = step(s, batch)
    w_ref = np.asarray(s.params["w"])

    # interrupted + restored
    mgr = CheckpointManager(str(tmp_path))
    s = init_state(params, opt)
    for _ in range(2):
        s, _ = step(s, batch)
    mgr.save(jax.tree.map(np.asarray, s), step=2)
    restored, step_no = mgr.restore_latest(jax.tree.map(np.asarray, s))
    assert step_no == 2
    s2 = IplsTrainState(*jax.tree.map(jnp.asarray, restored))
    for _ in range(2):
        s2, _ = step(s2, batch)
    np.testing.assert_allclose(np.asarray(s2.params["w"]), w_ref, rtol=1e-6)
