"""End-to-end behaviour tests for the paper's system: the full IPLS protocol
training the paper's model on the simulated substrate, plus the datacenter
train-step built end-to-end through the launcher on the smoke mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig


def test_end_to_end_ipls_training():
    """Boot 3 agents, train the paper's MLP for 5 rounds over simulated
    IPFS, verify the assembled global model improved and every agent
    converged to (nearly) the same model."""
    x_tr, y_tr, x_te, y_te = synth_mnist(num_train=2000, num_test=500, seed=1)
    shards = iid_split(x_tr, y_tr, 3, seed=1)
    cfg = SimConfig(num_agents=3, num_partitions=6, pi=2, rho=2, rounds=5, local_iters=5)
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    hist = sim.run()
    assert hist[-1]["acc_mean"] > hist[0]["acc_mean"] + 0.2
    # agents agree: per-agent accuracy spread is small by the last round
    assert hist[-1]["acc_std"] < 0.08
    # traffic was metered
    assert sim.net.pubsub.total_bytes() > 0


def test_end_to_end_datacenter_train_step():
    """Build the full launcher path (model -> shardings -> jit) on the
    1-device smoke mesh with a reduced arch, run 3 real steps, loss drops."""
    from repro.configs import get_config, build_model
    from repro.configs.registry import ShapeSpec
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.steps import build_train_step
    from repro.core.sharded import init_state, IplsStepConfig
    from repro.optim import sgd

    cfg = get_config("internlm2-1.8b", reduced=True)
    model = build_model(cfg)
    mesh = make_smoke_mesh()
    shape = ShapeSpec("smoke", seq_len=32, global_batch=4, kind="train")
    opt = sgd(0.5)
    built = build_train_step(
        model, mesh, shape,
        optimizer=opt,
        step_cfg=IplsStepConfig(grad_clip=1.0),
    )
    params = model.init(0)
    state = init_state(params, opt)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 256, (4, 32)), jnp.int32),
        "participation": jnp.ones((4,), jnp.float32),
    }
    step = jax.jit(built.fn, in_shardings=built.in_shardings, out_shardings=built.out_shardings)
    with built.mesh:
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(state.step) == 3
    assert not np.isnan(losses[-1])


def test_elastic_restart_from_checkpoint(tmp_path):
    """Fault tolerance at the datacenter layer: kill after step 2, restore,
    continue — state matches an uninterrupted run."""
    from repro.checkpoint import CheckpointManager
    from repro.core.sharded import init_state, make_train_step, IplsStepConfig, IplsTrainState
    from repro.optim import sgd

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["y"]), axis=-1), {}

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    batch = {
        "x": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
    }
    opt = sgd(0.1)
    step = jax.jit(make_train_step(loss_fn, opt, IplsStepConfig(use_eps=False, grad_clip=None)))

    # uninterrupted
    s = init_state(params, opt)
    for _ in range(4):
        s, _ = step(s, batch)
    w_ref = np.asarray(s.params["w"])

    # interrupted + restored
    mgr = CheckpointManager(str(tmp_path))
    s = init_state(params, opt)
    for _ in range(2):
        s, _ = step(s, batch)
    mgr.save(jax.tree.map(np.asarray, s), step=2)
    restored, step_no = mgr.restore_latest(jax.tree.map(np.asarray, s))
    assert step_no == 2
    s2 = IplsTrainState(*jax.tree.map(jnp.asarray, restored))
    for _ in range(2):
        s2, _ = step(s2, batch)
    np.testing.assert_allclose(np.asarray(s2.params["w"]), w_ref, rtol=1e-6)
