"""Telemetry acceptance: one metric stream, three engines, zero overhead off.

The contract (docs/TELEMETRY.md): with telemetry enabled, the scalar pubsub
oracle, the vectorized engine, and the multi-round scanned engine emit
byte-for-byte identical JSONL metric streams under identical configs —
PERFECT and LOSSY conditions, replication 1..3, f32 and int8 wire. With it
disabled, the engines compute bitwise-identical results to the enabled run
(the metric aux outputs observe, never perturb).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import SimConfig, make_simulation
from repro.p2p.network import LOSSY, PERFECT, NetworkConditions
from repro.telemetry import MetricsRecorder, PhaseTimer, TraceWriter
from repro.telemetry.report import load_stream, main as report_main, summarize
from repro.telemetry.schema import CHANNELS, ROW_KEYS, SCHEMA_VERSION


@pytest.fixture(scope="module")
def data():
    return synth_mnist(num_train=900, num_test=200, seed=0)


def _run(data, engine, scan=0, telemetry=True, **kw):
    x_tr, y_tr, x_te, y_te = data
    cfg = SimConfig(
        num_agents=6, num_partitions=5, pi=2, rounds=3, local_iters=2,
        batch_size=32, eval_agents=2, engine=engine, scan_rounds=scan,
        telemetry=telemetry, **kw,
    )
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim = make_simulation(cfg, shards, x_te, y_te)
    sim.run()
    return sim


# ---- the acceptance bar: byte-identical streams across engines --------------
@pytest.mark.parametrize(
    "kw",
    [
        # rho=3 exercises the ordered replica merges; PERFECT and LOSSY
        # take entirely different vectorized paths (phase tables vs events)
        dict(conditions=PERFECT, rho=3),
        dict(conditions=LOSSY, rho=3),
        # the int8 wire quantizes deltas AND the accounting (4x fewer bytes)
        dict(conditions=LOSSY, rho=2, wire_dtype="int8"),
    ],
    ids=["perfect-rho3", "lossy-rho3", "lossy-int8"],
)
def test_metric_streams_byte_identical_across_engines(data, kw):
    sims = [
        _run(data, "scalar", **kw),
        _run(data, "vectorized", **kw),
        _run(data, "vectorized", scan=2, **kw),
    ]
    streams = [s.recorder.jsonl_lines()[1:] for s in sims]
    assert streams[0] == streams[1] == streams[2]
    assert len(streams[0]) == 3  # one row per round


def test_rows_follow_the_schema(data):
    sim = _run(data, "scalar", conditions=LOSSY, rho=2)
    for row in sim.recorder.rows:
        assert tuple(row) == ROW_KEYS
    lines = sim.recorder.jsonl_lines(meta={"engine": "scalar"})
    head = json.loads(lines[0])
    assert head["schema_version"] == SCHEMA_VERSION
    assert head["meta"] == {"engine": "scalar"}
    # lossy traffic actually landed in the channel columns
    total_msgs = sum(
        r[f"msgs_{ch}"] for r in sim.recorder.rows for ch in CHANNELS
    )
    assert total_msgs > 0
    assert sim.recorder.rows[-1]["msgs_total"] == sim.net.pubsub.messages_sent


# ---- disabled telemetry is invisible ---------------------------------------
@pytest.mark.parametrize("engine,scan", [("vectorized", 0), ("vectorized", 2)])
def test_disabled_telemetry_changes_nothing(data, engine, scan):
    kw = dict(conditions=LOSSY, rho=2, seed=3)
    on = _run(data, engine, scan=scan, telemetry=True, **kw)
    off = _run(data, engine, scan=scan, telemetry=False, **kw)
    assert off.recorder is None
    np.testing.assert_array_equal(on.agent_weights(), off.agent_weights())
    for a, b in zip(on.history, off.history):
        assert a == b
    assert on._bytes_total == off._bytes_total


def test_scalar_engine_disabled_telemetry_changes_nothing(data):
    kw = dict(conditions=LOSSY, rho=2, seed=3)
    on = _run(data, "scalar", telemetry=True, **kw)
    off = _run(data, "scalar", telemetry=False, **kw)
    assert off.recorder is None
    for a in range(6):
        np.testing.assert_array_equal(
            on.agents[a].load_model(), off.agents[a].load_model()
        )
    for ra, rb in zip(on.history, off.history):
        assert ra == rb


# ---- hypothesis: stream equality is seed/condition independent --------------
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None)
    @given(
        seed=st.integers(0, 50),
        loss=st.sampled_from([0.0, 0.15, 0.4]),
    )
    def test_stream_equality_property(seed, loss):
        # module-scoped fixtures don't mix with @given; tiny fixed-shape
        # config so every example reuses the same compiled programs
        x_tr, y_tr, x_te, y_te = synth_mnist(num_train=400, num_test=80, seed=1)
        cond = NetworkConditions(loss_prob=loss, delay_prob=0.2, max_delay_rounds=2)
        cfg = SimConfig(
            num_agents=4, num_partitions=3, pi=2, rho=2, rounds=2,
            local_iters=1, batch_size=32, eval_agents=1, seed=seed,
            conditions=cond, telemetry=True,
        )
        shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
        sims = []
        for engine in ("scalar", "vectorized"):
            sim = make_simulation(
                dataclasses.replace(cfg, engine=engine), shards, x_te, y_te
            )
            sim.run()
            sims.append(sim)
        a, b = (s.recorder.jsonl_lines()[1:] for s in sims)
        assert a == b
except ImportError:  # pragma: no cover - hypothesis is in the base image
    pass


# ---- protocol traces --------------------------------------------------------
def test_trace_events_are_chrome_trace_shaped(data, tmp_path):
    sim = _run(data, "scalar", conditions=LOSSY, rho=2, trace=True)
    trace = sim.recorder.trace
    assert trace is not None
    doc = trace.to_dict()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "i", "X"} <= phases  # metadata + protocol instants + spans
    for ev in events:
        assert {"name", "ph", "pid"} <= set(ev)
        if ev["ph"] in ("i", "X"):
            assert ev["ts"] >= 0
    # both tracks populated: simulated-tick protocol + wall-clock host
    assert any(e["pid"] == 1 and e["ph"] == "i" for e in events)
    assert any(e["pid"] == 2 and e["ph"] == "X" for e in events)
    # round-trips through json on disk
    out = tmp_path / "run.trace.json"
    trace.write(str(out))
    assert json.loads(out.read_text())["traceEvents"]


def test_phase_timer_accumulates_and_traces():
    tw = TraceWriter()
    pt = PhaseTimer(trace=tw)
    with pt.phase("fate_draw"):
        pass
    with pt.phase("fate_draw"):
        pass
    s = pt.summary()
    assert s["fate_draw"]["count"] == 2
    assert s["fate_draw"]["total_s"] >= 0
    assert len(tw.events) == 2


# ---- report CLI -------------------------------------------------------------
def test_report_cli_digest(data, tmp_path, capsys):
    sim = _run(data, "vectorized", conditions=LOSSY, rho=2)
    path = tmp_path / "metrics.jsonl"
    sim.recorder.write_jsonl(str(path), meta={"engine": "vectorized"})
    head, rows = load_stream(str(path))
    assert head["schema_version"] == SCHEMA_VERSION
    assert len(rows) == 3
    digest = summarize(rows)
    assert digest["rounds"] == 3
    assert digest["msgs_total"] == rows[-1]["msgs_total"]
    assert report_main([str(path)]) == 0
    assert "rounds 0..2" in capsys.readouterr().out
    assert report_main([str(path), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload[str(path)]["rounds"] == 3


def test_report_cli_rejects_foreign_schema(tmp_path, capsys):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema_version":99,"meta":{}}\n')
    assert report_main([str(bad)]) == 1


# ---- recorder unit behavior -------------------------------------------------
def test_recorder_channel_mapping_matches_fates():
    rec = MetricsRecorder(ticks_per_round=4, max_delay_ticks=2)
    # REPLY at tick phase 1 is a fetch reply; at phase 3 an update reply
    from repro.core.api import REPLY_TOPIC, UPDATE_TOPIC

    rec.on_send(REPLY_TOPIC, 1, sender=0, nbytes=100)
    rec.on_send(REPLY_TOPIC, 3, sender=0, nbytes=100)
    rec.on_send(UPDATE_TOPIC, 2, sender=1, nbytes=50)
    rec.finish_round(
        round=0, active=2, contrib=[1], eps=[1.0], delta_normsq=0.0,
        value_normsq=0.0, accs=[0.5], bytes_total=250, msgs_total=3,
        drops_total=0,
    )
    row = rec.rows[0]
    assert row["msgs_fetch_reply"] == 1
    assert row["msgs_update_reply"] == 1
    assert row["msgs_update"] == 1
    assert row["bytes_update"] == 50
