"""Quantized wire (int8 + error feedback) end-to-end equivalence.

The int8 delta plane must not open a gap between the engines: the scalar
oracle quantizes on the host (core/wire.py), the vectorized/scanned engines
quantize on device (kernels/quantize) and dequantize INSIDE the fused
aggregation kernel (kernels/ipls_aggregate, batched_q variant). Because the
codec's scales are exact powers of two, every transport op is exact in f32
and the three engines stay equivalent under loss, delay and replication —
with EXACT traffic counters, at ~4x fewer wire bytes than the f32 plane.
"""
import dataclasses

import numpy as np
import pytest

from repro.data import iid_split, synth_mnist
from repro.fl import IPLSSimulation, SimConfig
from repro.fl.vectorized import VectorizedIPLSSimulation
from repro.p2p.network import LOSSY, PERFECT


@pytest.fixture(scope="module")
def data():
    return synth_mnist(num_train=1500, num_test=300, seed=0)


def _cfg(**kw):
    base = dict(
        num_agents=4, num_partitions=4, pi=2, rounds=4, lr=0.1,
        local_iters=2, batch_size=32, eval_agents=2, seed=3,
        conditions=LOSSY, wire_dtype="int8",
    )
    base.update(kw)
    return SimConfig(**base)


def _run_scalar(cfg, data):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    sim = IPLSSimulation(cfg, shards, x_te, y_te)
    sim.run()
    w = np.stack([sim.agents[a].load_model() for a in range(cfg.num_agents)])
    return w, (
        sim.net.pubsub.total_bytes(),
        sim.net.pubsub.messages_sent,
        sim.net.pubsub.messages_dropped,
    )


def _run_vec(cfg, data, use_kernel=True, scan_rounds=0):
    x_tr, y_tr, x_te, y_te = data
    shards = iid_split(x_tr, y_tr, cfg.num_agents, seed=0)
    cfg = dataclasses.replace(cfg, scan_rounds=scan_rounds)
    sim = VectorizedIPLSSimulation(cfg, shards, x_te, y_te, use_kernel=use_kernel)
    sim.run()
    return sim.agent_weights(), (
        sim._bytes_total, sim.messages_sent, sim.messages_dropped,
    )


# acceptance bar for the quantized delta plane: scalar, vectorized and
# scanned engines agree (weights <= 1e-4; bytes/messages/drops exactly)
# under LOSSY conditions across every replication factor
@pytest.mark.parametrize(
    "kw",
    [
        dict(rho=1),
        dict(rho=2),
        dict(rho=3),
        dict(rho=2, conditions=PERFECT),
        dict(rho=2, wire_dtype="f32"),  # control: the f32 plane, same matrix
    ],
    ids=["rho1", "rho2", "rho3", "perfect", "f32-control"],
)
def test_quantized_engines_equivalent(data, kw):
    cfg = _cfg(**kw)
    w_s, t_s = _run_scalar(cfg, data)
    w_v, t_v = _run_vec(cfg, data)
    w_c, t_c = _run_vec(cfg, data, scan_rounds=2)
    assert t_s == t_v == t_c, f"traffic counters diverged: {t_s} {t_v} {t_c}"
    np.testing.assert_allclose(w_s, w_v, atol=1e-4)
    # both device paths share one compilation story: bitwise identical
    np.testing.assert_array_equal(w_v, w_c)
    if cfg.conditions.loss_prob > 0:
        assert t_v[2] > 0  # losses actually happened


def test_quantized_cpu_fallback_matches_scalar(data):
    """use_kernel=False routes through the jnp q-oracle (einsum dequant-
    aggregate) — same wire codes, float-noise-level difference only."""
    cfg = _cfg(rho=2)
    w_s, t_s = _run_scalar(cfg, data)
    w_v, t_v = _run_vec(cfg, data, use_kernel=False)
    assert t_s == t_v
    np.testing.assert_allclose(w_s, w_v, atol=1e-4)


# the perf claim: int8 codes + f32 pow2 block scales cut UpdateModel and
# fetch/reply/replica transfer bytes ~4x; headers and the one-time f32
# join bootstrap keep the end-to-end ratio just under that
@pytest.mark.parametrize("rho", [1, 3])
def test_quantized_wire_cuts_bytes(data, rho):
    bytes_by_mode = {}
    for wd in ("f32", "int8"):
        cfg = _cfg(rho=rho, rounds=8, eval_agents=0, wire_dtype=wd)
        _, (nbytes, _, _) = _run_vec(cfg, data)
        bytes_by_mode[wd] = nbytes
    ratio = bytes_by_mode["f32"] / bytes_by_mode["int8"]
    assert ratio >= 3.5, f"rho={rho}: compression ratio {ratio:.3f} < 3.5"


def test_wire_size_accounting_matches_payloads():
    """The byte meter charges exactly what the codec ships: n int8 codes
    plus one f32 scale per 1024-block (f32: 4n)."""
    from repro.core.wire import Int8Wire, make_wire, wire_size

    rng = np.random.default_rng(0)
    for n in (1, 1023, 1024, 2500):
        x = rng.standard_normal(n).astype(np.float32)
        payload, nb = Int8Wire().encode_value(x)
        assert nb == wire_size(n, "int8") == n + 4 * ((n + 1023) // 1024)
        assert wire_size(n, "f32") == 4 * n
        np.testing.assert_array_equal(
            make_wire("f32").decode(make_wire("f32").encode_value(x)[0]), x
        )
