"""The datacenter mapping (core/sharded.py): the IPLS train step's semantics
— eps weighting, participation masking, ZeRO-1 sharding specs — verified on
the 1-device smoke mesh (same code path as the 256-chip mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.sharded import (
    IplsStepConfig,
    init_state,
    make_train_step,
    spec_for_leaf,
    state_shardings,
)
from repro.launch.mesh import make_smoke_mesh
from repro.optim import adam, sgd


def tiny_loss(params, batch):
    pred = batch["x"] @ params["w"]
    per_ex = jnp.mean(jnp.square(pred - batch["y"]), axis=-1)
    return per_ex, {}


def make_inputs(B=8, D=4):
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((D, D)), jnp.float32)}
    batch = {
        "x": jnp.asarray(rng.standard_normal((B, D)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal((B, D)), jnp.float32),
        "participation": jnp.ones((B,), jnp.float32),
    }
    return params, batch


def test_eps_weighted_step_matches_manual():
    params, batch = make_inputs()
    opt = sgd(0.1)
    step = make_train_step(tiny_loss, opt, IplsStepConfig(alpha=0.5, grad_clip=None), num_agents=4)
    state = init_state(params, opt)
    new_state, metrics = jax.jit(step)(state, batch)
    # eps (paper): eps1 = 0.5*1 + 0.5/4 = 0.625, applied scale = eps1*r = 2.5
    grads = jax.grad(lambda p: tiny_loss(p, batch)[0].mean())(params)
    want = params["w"] - 2.5 * 0.1 * grads["w"]
    np.testing.assert_allclose(np.asarray(new_state.params["w"]), np.asarray(want), rtol=1e-5)
    assert np.isclose(float(new_state.eps), 0.625)


def test_participation_mask_drops_agents():
    params, batch = make_inputs(B=8)
    batch["participation"] = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    opt = sgd(0.1)
    step = make_train_step(tiny_loss, opt, IplsStepConfig(alpha=0.5, grad_clip=None), num_agents=2)
    state = init_state(params, opt)
    new_state, metrics = jax.jit(step)(state, batch)
    # equals training on only the first half of the batch
    half = {k: v[:4] if k != "participation" else jnp.ones((4,)) for k, v in batch.items()}
    grads = jax.grad(lambda p: tiny_loss(p, half)[0].mean())(params)
    want = params["w"] - 0.1 * grads["w"]
    np.testing.assert_allclose(np.asarray(new_state.params["w"]), np.asarray(want), rtol=1e-5)
    assert np.isclose(float(metrics["participation"]), 0.5)
    # r = 1 participant of 2 agents -> eps = 0.5 + 0.5/1 = 1.0
    assert np.isclose(float(new_state.eps), 1.0)


def test_accumulation_matches_full_batch():
    params, batch = make_inputs(B=8)
    opt = sgd(0.1)
    s1 = make_train_step(tiny_loss, opt, IplsStepConfig(use_eps=False, grad_clip=None))
    s2 = make_train_step(tiny_loss, opt, IplsStepConfig(use_eps=False, grad_clip=None, accum_steps=2))
    st = init_state(params, opt)
    w1 = jax.jit(s1)(st, batch)[0].params["w"]
    w2 = jax.jit(s2)(st, batch)[0].params["w"]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5)


def test_zero1_spec_adds_data_axis():
    mesh = make_smoke_mesh()
    # ffn dim maps to model; zero1 adds data on the remaining dim
    spec = spec_for_leaf(("embed", "ffn"), (64, 128), mesh, {"embed": None, "ffn": "model"}, "data")
    assert spec == P("data", "model")
    # already-sharded dim gets sub-axis sharding when divisible
    spec = spec_for_leaf(("ffn",), (128,), mesh, {"ffn": "model"}, "data")
    assert spec == P(("model", "data"))


def test_state_shardings_structure():
    mesh = make_smoke_mesh()
    params, _ = make_inputs()
    opt = adam(1e-3)
    axes = {"w": ("embed", "ffn")}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    sh = state_shardings(axes, shapes, opt, mesh)
    # params replicated over data (LoadModel layout); opt sharded (ZeRO-1)
    assert "data" not in str(sh.params["w"].spec)
    assert "data" in str(sh.opt_state["w"].m.spec)
    assert sh.eps.spec == P()


def test_fsdp_param_shardings():
    mesh = make_smoke_mesh()
    params, _ = make_inputs()
    opt = adam(1e-3)
    axes = {"w": ("embed", "ffn")}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    sh = state_shardings(axes, shapes, opt, mesh, fsdp=True)
    assert "data" in str(sh.params["w"].spec)  # lightweight storage
