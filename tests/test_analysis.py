"""Tests for the repro.analysis static-analysis suite.

Three layers: fixture tests (every rule has >=1 fire and >=1 no-fire case
under ``tests/analysis_fixtures/``), CLI contract tests (exit codes, JSON
mode), and meta-tests (the live tree is clean modulo suppressions, the
protocol symmetry table is two-sided and matches the real engines).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    Options,
    all_rules,
    analyze_file,
    analyze_paths,
    analyze_source,
    rules_protocol,
)

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
REPO = Path(__file__).resolve().parents[1]

# fixture file -> the one rule it must fire (and nothing else may fire)
FIRE_CASES = {
    "pl01_fire.py": "PL01",
    "pl02_fire.py": "PL02",
    "pl03_fire.py": "PL03",
    "pl04_fire.py": "PL04",
    "pl05_fire.py": "PL05",
    "jx01_fire.py": "JX01",
    "jx02_fire.py": "JX02",
    "jx03_fire.py": "JX03",
    "jx04_fire.py": "JX04",
    "jx05_fire.py": "JX05",
    "pr01_fire.py": "PR01",
    "pr02_fire.py": "PR02",
    "pr03_fire.py": "PR03",
    "pr04_fire.py": "PR04",
}

OK_CASES = [
    "pallas_ok.py",
    "jax_ok.py",
    "protocol_ok.py",
    "noqa_ok.py",
    "fl/vectorized.py",
]


def _rules(findings):
    return {f.rule for f in findings}


@pytest.mark.parametrize("name,rule", sorted(FIRE_CASES.items()))
def test_rule_fires_on_known_bad_fixture(name, rule):
    findings = analyze_file(FIXTURES / name)
    assert rule in _rules(findings), f"{name}: expected {rule} to fire"
    assert _rules(findings) == {rule}, (
        f"{name}: unexpected extra findings {findings}"
    )


@pytest.mark.parametrize("name", OK_CASES)
def test_no_fire_on_known_good_fixture(name):
    findings = analyze_file(FIXTURES / name)
    assert findings == [], f"{name}: expected clean, got {findings}"


def test_every_rule_has_a_fire_fixture():
    assert set(FIRE_CASES.values()) == set(all_rules().keys())


def test_every_pack_has_fire_and_no_fire_coverage():
    packs = {r.pack for r in all_rules().values()}
    assert packs == {"pallas", "jax", "protocol"}
    # each pack's ok twin exists alongside its fire fixtures
    for prefix, ok in [("pl", "pallas_ok.py"), ("jx", "jax_ok.py"), ("pr", "protocol_ok.py")]:
        assert any(n.startswith(prefix) for n in FIRE_CASES)
        assert (FIXTURES / ok).exists()


def test_live_tree_clean_modulo_suppressions():
    findings = analyze_paths(
        [REPO / "src", REPO / "tests", REPO / "benchmarks"]
    )
    assert findings == [], "\n".join(f.render() for f in findings)


def test_noqa_requires_matching_rule_id():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return int(x)  # repro: noqa[JX02] wrong id does not suppress\n"
    )
    assert _rules(analyze_source("f.py", src)) == {"JX01"}
    src_ok = src.replace("noqa[JX02]", "noqa[JX01]")
    assert analyze_source("f.py", src_ok) == []


def test_select_option_filters_rules():
    findings = analyze_file(FIXTURES / "pl03_fire.py", Options(select={"PL04"}))
    assert findings == []


def test_syntax_error_is_a_finding():
    findings = analyze_source("broken.py", "def f(:\n")
    assert _rules(findings) == {"SYNTAX"}


def test_symmetry_table_is_two_sided():
    sides = rules_protocol.symmetry_is_balanced()
    assert sides["scalar"], "scalar engine has no declared accounting sites"
    assert sides["scalar"] == sides["vectorized"], (
        "every counter family needs a site in BOTH engines: "
        f"{sides}"
    )


def test_symmetry_table_matches_real_engines():
    # the declared files exist and declared functions are present — a rename
    # would silently turn declarations stale without this
    for suffix, funcs in rules_protocol.SYMMETRY.items():
        path = REPO / "src" / "repro" / suffix
        assert path.exists(), f"SYMMETRY references missing file {suffix}"
        text = path.read_text()
        for fn in funcs:
            assert f"def {fn}(" in text, f"{suffix}: declared '{fn}' not found"
    for suffix, fn in rules_protocol.EMITTER_FUNCS.items():
        path = REPO / "src" / "repro" / suffix
        assert path.exists(), f"EMITTER_FUNCS references missing file {suffix}"
        assert f"def {fn}(" in path.read_text(), (
            f"{suffix}: declared emitter '{fn}' not found"
        )


def test_pr04_schema_mirror_matches_live_schema():
    # PR04 carries a hardcoded copy of the telemetry schema so the analyzer
    # stays importable without the telemetry package; this pins the mirror
    from repro.telemetry import schema

    assert rules_protocol.METRIC_FINISH_KEYS == schema.FINISH_KEYS
    assert rules_protocol.METRIC_CHANNELS == schema.CHANNELS


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True,
        text=True,
        cwd=REPO,
        env=env,
    )


def test_cli_exits_nonzero_on_known_bad_fixture():
    proc = _run_cli(str(FIXTURES / "pl02_fire.py"))
    assert proc.returncode == 1
    assert "PL02" in proc.stdout


def test_cli_exits_zero_on_clean_tree():
    proc = _run_cli("src", "tests", "benchmarks")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_json_output_is_machine_readable():
    proc = _run_cli(str(FIXTURES / "jx01_fire.py"), "--json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload and payload[0]["rule"] == "JX01"
    assert {"rule", "path", "line", "message"} <= set(payload[0])
