"""The trip-count-aware HLO cost analyzer: verified against hand-computable
programs (this is what makes the roofline table honest for scanned models)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_cost import analyze_hlo_text


def _cost(fn, *args):
    return analyze_hlo_text(jax.jit(fn).lower(*args).compile().as_text())


def test_single_matmul_flops():
    a = jnp.zeros((256, 256), jnp.float32)
    c = _cost(lambda x: x @ x, a)
    assert np.isclose(c.flops, 2 * 256**3, rtol=0.01)


def test_scan_multiplies_by_trip_count():
    a = jnp.zeros((256, 256), jnp.float32)

    def scanned(x):
        x, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=7)
        return x

    c = _cost(scanned, a)
    assert np.isclose(c.flops, 7 * 2 * 256**3, rtol=0.01)


def test_nested_scan():
    a = jnp.zeros((128, 128), jnp.float32)

    def nested(x):
        def outer(c, _):
            c, _ = jax.lax.scan(lambda ci, _: (ci @ ci, None), c, None, length=4)
            return c, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return x

    c = _cost(nested, a)
    assert np.isclose(c.flops, 12 * 2 * 128**3, rtol=0.01)


def test_batched_dot_flops():
    a = jnp.zeros((4, 64, 64), jnp.float32)
    c = _cost(lambda x: jnp.einsum("bij,bjk->bik", x, x), a)
    assert np.isclose(c.flops, 4 * 2 * 64**3, rtol=0.01)


def test_bytes_scale_with_trips():
    a = jnp.zeros((256, 256), jnp.float32)

    def scanned(x):
        x, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return x

    c1 = _cost(lambda x: x @ x, a)
    c10 = _cost(scanned, a)
    assert c10.bytes > 5 * c1.bytes  # roughly linear in trips


def test_roofline_report_terms():
    from repro.roofline.analysis import RooflineReport

    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=1e18, hlo_bytes=1e15, collective_bytes={"all-reduce": 5e10},
        model_flops=5e17,
    )
    assert np.isclose(r.compute_s, 1e18 / (256 * 197e12))
    assert np.isclose(r.memory_s, 1e15 / (256 * 819e9))
    assert np.isclose(r.collective_s, 5e10 / 50e9)
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.roofline_fraction <= 1.0
    assert np.isclose(r.useful_flops_ratio, 0.5)
