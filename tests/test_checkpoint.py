"""Checkpoint/restore: roundtrip, atomicity marker, GC, sharded writes."""
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import latest_step


def tree():
    return {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "step": np.asarray(7, np.int32),
        "eps": np.asarray(0.5, np.float32),
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), t, step=7)
    got, step = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    np.testing.assert_array_equal(got["params"]["w"], t["params"]["w"])
    np.testing.assert_array_equal(got["eps"], t["eps"])


def test_latest_step_ignores_incomplete(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), t, step=3)
    # fake an incomplete checkpoint (no COMMITTED marker)
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 3


def test_shape_mismatch_rejected(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), t, step=1)
    bad = {"params": {"w": np.zeros((2, 2), np.float32)}, "step": t["step"], "eps": t["eps"]}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_manager_keep_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        t["step"] = np.asarray(s, np.int32)
        mgr.save_async(t, step=s)
    mgr.wait()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    got, step = mgr.restore_latest(t)
    assert step == 4 and int(got["step"]) == 4


def test_sharded_checkpoint(tmp_path):
    """Each IPLS partition owner writes only its shard (scale-out writes)."""
    shard0 = {"w": np.zeros((4,), np.float32)}
    shard1 = {"w": np.ones((4,), np.float32)}
    save_checkpoint(str(tmp_path), shard0, step=5, shard_id=0, num_shards=2)
    assert latest_step(str(tmp_path), num_shards=2) is None  # incomplete
    save_checkpoint(str(tmp_path), shard1, step=5, shard_id=1, num_shards=2)
    assert latest_step(str(tmp_path), num_shards=2) == 5
    got0, _ = restore_checkpoint(str(tmp_path), shard0, shard_id=0, num_shards=2)
    got1, _ = restore_checkpoint(str(tmp_path), shard1, shard_id=1, num_shards=2)
    np.testing.assert_array_equal(got0["w"], shard0["w"])
    np.testing.assert_array_equal(got1["w"], shard1["w"])
