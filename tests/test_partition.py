"""Partition-table tests, including the paper's own worked example (§2.1)."""

from repro.core.partition import PartitionSpec, PartitionTable, flatten_params, unflatten_params

import numpy as np


def test_paper_example_pi4_rho2():
    """Paper §2.1: K=6, pi=4, rho=2. Agent 1 bootstraps with all 6; agent 2
    takes 4; agent 3 takes 4; a fourth agent cannot store anything."""
    t = PartitionTable(num_partitions=6, pi=4, rho=2)
    t.bootstrap(1)
    assert t.partitions_of(1) == [0, 1, 2, 3, 4, 5]
    t.join(2)
    assert t.load(2) == 4
    # paper: agent 1 'remains responsible for' 4 partitions; 2 transferred +
    # 2 replicated => 8 total slots with two partitions at rho=2
    assert t.load(1) + t.load(2) == 8
    t.join(3)
    assert t.load(3) == 4
    # every partition replicated at most twice
    for p in range(6):
        assert 1 <= t.replication(p) <= 2
    # all partitions now at rho=2 (total slots 12 = 3 agents * 4)
    assert sum(t.replication(p) for p in range(6)) == 12
    t.join(4)
    assert t.load(4) == 0  # paper: 'New agents cannot store any partition'
    t.validate()


def test_join_transfers_from_overloaded():
    t = PartitionTable(num_partitions=8, pi=2, rho=1)
    t.bootstrap(0)
    t.join(1)
    # rho=1: replication impossible; the new agent must TAKE partitions
    assert t.load(1) == 2
    assert t.load(0) == 6
    for p in range(8):
        assert t.replication(p) == 1
    t.validate()


def test_leave_hands_off_orphans():
    t = PartitionTable(num_partitions=4, pi=2, rho=1)
    t.bootstrap(0)
    t.join(1)
    held = t.partitions_of(1)
    t.leave(1)
    assert t.coverage()
    for p in held:
        assert t.holders_of(p) == [0]
    t.validate()


def test_leave_with_replicas_no_handoff_needed():
    t = PartitionTable(num_partitions=4, pi=4, rho=2)
    t.bootstrap(0)
    t.join(1)
    handoff = t.leave(1)
    assert t.coverage()
    assert all(v is None for v in handoff.values())


def test_fail_reassigns():
    t = PartitionTable(num_partitions=6, pi=3, rho=1)
    t.bootstrap(0)
    t.join(1)
    t.join(2)
    t.fail(0)
    assert t.coverage()
    t.validate()


def test_spec_even():
    s = PartitionSpec.even(103, 10)
    assert s.num_partitions == 10
    assert s.total == 103
    assert max(s.sizes) - min(s.sizes) <= 1
    offs = s.offsets()
    assert offs[0] == 0 and offs[-1] + s.sizes[-1] == 103


def test_flatten_roundtrip():
    params = {"a": np.arange(6, dtype=np.float32).reshape(2, 3), "b": {"c": np.ones(4, np.float32)}}
    vec, layout = flatten_params(params)
    back = unflatten_params(vec, layout)
    np.testing.assert_array_equal(back["a"], params["a"])
    np.testing.assert_array_equal(back["b"]["c"], params["b"]["c"])
