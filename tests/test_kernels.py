"""Per-kernel allclose sweeps: shapes x dtypes vs the pure-jnp oracles,
all in interpret mode (the kernel body executes on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(7)


# --- ipls_aggregate ---------------------------------------------------------
@pytest.mark.parametrize("N", [128, 4096, 70001])
@pytest.mark.parametrize("R", [1, 3, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ipls_aggregate(N, R, dtype):
    from repro.kernels.ipls_aggregate.ops import aggregate
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_ref

    w = jnp.asarray(RNG.standard_normal(N), dtype)
    d = jnp.asarray(RNG.standard_normal((R, N)), dtype)
    m = jnp.asarray(RNG.integers(0, 2, R), jnp.float32)
    eps = jnp.asarray(0.6, jnp.float32)
    got = aggregate(w, d, m, eps)
    ref = ipls_aggregate_ref(w, d, m, eps)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# variable-r instance tables: R spans multiple R_TILE chunks of the batched
# grid (lossy rounds carry up to 1 + (A-1)*(1+max_delay) contributor slots)
# and zero-contributor rows must pass through bit-exactly
@pytest.mark.parametrize("R", [7, 8, 9, 23])
def test_ipls_aggregate_batched_variable_r(R):
    from repro.kernels.ipls_aggregate.ops import aggregate_batched
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_batched_ref

    K, N = 5, 4097
    w = jnp.asarray(RNG.standard_normal((K, N)), jnp.float32)
    d = jnp.asarray(RNG.standard_normal((K, R, N)), jnp.float32)
    m = jnp.asarray(RNG.integers(0, 2, (K, R)), jnp.float32)
    m = m.at[3].set(0.0)  # zero-contributor round
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = aggregate_batched(w, d, m, eps)
    ref = ipls_aggregate_batched_ref(w, d, m, eps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(w[3]))


# --- flash attention ---------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 2, 128, 64), (2, 2, 256, 128), (1, 1, 384, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(shape, causal, dtype):
    from repro.kernels.flash_attention.ops import attention
    from repro.kernels.flash_attention.ref import mha_ref

    B, H, S, D = shape
    q = jnp.asarray(RNG.standard_normal(shape), dtype)
    k = jnp.asarray(RNG.standard_normal(shape), dtype)
    v = jnp.asarray(RNG.standard_normal(shape), dtype)
    got = attention(q, k, v, causal=causal)
    ref = mha_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_gqa_repeat():
    from repro.kernels.flash_attention.ops import attention
    from repro.kernels.flash_attention.ref import mha_ref

    q = jnp.asarray(RNG.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
    got = attention(q, k, v)
    ref = mha_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


# --- decode attention ---------------------------------------------------------
@pytest.mark.parametrize("shape", [(2, 4, 256, 64), (1, 8, 512, 128)])
@pytest.mark.parametrize("pos_frac", [0.0, 0.4, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(shape, pos_frac, dtype):
    from repro.kernels.decode_attention.ops import decode
    from repro.kernels.decode_attention.ref import decode_ref

    B, H, S, D = shape
    pos = int((S - 1) * pos_frac)
    q = jnp.asarray(RNG.standard_normal((B, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal(shape), dtype)
    v = jnp.asarray(RNG.standard_normal(shape), dtype)
    got = decode(q, k, v, pos)
    ref = decode_ref(q, k, v, jnp.asarray(pos))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# --- rwkv6 linear scan ----------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 64, 2, 32), (2, 128, 2, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_scan(shape, dtype):
    from repro.kernels.linear_scan.ops import linear_scan
    from repro.kernels.linear_scan.ref import rwkv6_ref

    B, T, H, K = shape
    r = jnp.asarray(RNG.standard_normal(shape) * 0.5, dtype)
    k = jnp.asarray(RNG.standard_normal(shape) * 0.5, dtype)
    v = jnp.asarray(RNG.standard_normal(shape) * 0.5, dtype)
    logw = jnp.asarray(-np.exp(RNG.standard_normal(shape) * 0.5), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, K)) * 0.1, jnp.float32)
    got, gs = linear_scan(r, k, v, logw, u)
    ref, rs = rwkv6_ref(r, k, v, logw, u)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(np.asarray(gs), np.asarray(rs), atol=tol, rtol=tol)


# --- quantize ----------------------------------------------------------------------
@pytest.mark.parametrize("N", [8192, 100000])
def test_quantize_matches_ref_and_error_feedback(N):
    from repro.kernels.quantize.ops import compress
    from repro.kernels.quantize.ref import dequantize_ref, quantize_ref

    x = jnp.asarray(RNG.standard_normal(N), jnp.float32)
    e = jnp.asarray(RNG.standard_normal(N) * 0.01, jnp.float32)
    q, s, ne = compress(x, e)
    pad = (-N) % 8192
    qr, sr, ner = quantize_ref(jnp.pad(x, (0, pad)), jnp.pad(e, (0, pad)))
    assert np.array_equal(np.asarray(q), np.asarray(qr)[:N])
    np.testing.assert_allclose(np.asarray(ne), np.asarray(ner)[:N], atol=1e-6)
    # EF invariant: dequant(q) + new_err == x + err
    deq = dequantize_ref(qr, sr)[:N]
    np.testing.assert_allclose(np.asarray(deq + ne), np.asarray(x + e), atol=1e-5)


def test_quantize_block_size_agrees_across_layers():
    """wire.BLOCK, the quantize kernel BLOCK and the aggregation kernel's
    QBLOCK must agree or the fused dequantize reads the wrong scales."""
    from repro.core import wire
    from repro.kernels import quantize as qk
    from repro.kernels.ipls_aggregate import ipls_aggregate as agg

    assert wire.BLOCK == qk.quantize.BLOCK == agg.QBLOCK


@pytest.mark.parametrize("N", [1, 1023, 1024, 4097, 24576])
def test_quantize_pow2_scales_and_roundtrip_bound(N):
    """Codec invariants the engine equivalence proof rests on: scales are
    exact powers of two (or 0 for dead blocks), and the per-element
    round-trip error is bounded by one scale step."""
    from repro.core.wire import BLOCK, _np_dequantize, _np_quantize

    rng = np.random.default_rng(N)
    # wide dynamic range across blocks, plus a dead (all-tiny) block
    x = (rng.standard_normal(N) * 10.0 ** rng.integers(-8, 4, N)).astype(np.float32)
    if N > BLOCK:
        x[:BLOCK] = np.float32(1e-40)
    q, s, ne = _np_quantize(x, np.zeros(N, np.float32))
    # scales: zero or an exact power of two (mantissa bits all clear)
    nz = s[s > 0]
    assert np.all((nz.view(np.int32) & 0x007FFFFF) == 0)
    if N > BLOCK:
        assert s[0] == 0.0 and not np.any(q[:BLOCK])
    # per-element error <= scale of the element's block
    deq = _np_dequantize(q, s)[:N]
    err = np.abs(deq - x)
    pad = (-N) % BLOCK
    errb = np.pad(err, (0, pad)).reshape(-1, BLOCK)
    assert np.all(errb <= s[:, None] + 1e-30)
    # new_err is exactly the round-trip residual (pow2 arithmetic is exact)
    np.testing.assert_array_equal(ne, (x - deq).astype(np.float32))


def test_quantize_error_feedback_telescopes():
    """Streaming EF: after T steps the decoded stream plus the carried
    residual reconstructs the true cumulative signal — quantization error
    does not accumulate."""
    from repro.core.wire import Int8Wire

    wire = Int8Wire()
    rng = np.random.default_rng(11)
    n, steps = 3000, 7
    err = np.zeros(n, np.float32)
    cum_true = np.zeros(n, np.float64)
    cum_sent = np.zeros(n, np.float64)
    for _ in range(steps):
        x = (rng.standard_normal(n) * 0.05).astype(np.float32)
        payload, nb, err = wire.encode_delta(x, err)
        cum_true += x.astype(np.float64)
        cum_sent += wire.decode(payload).astype(np.float64)
        assert nb == n + 4 * ((n + 1023) // 1024)
    # telescoping: sum(decoded) + residual == sum(x) up to f32 add rounding
    np.testing.assert_allclose(
        cum_sent + err, cum_true, atol=steps * np.finfo(np.float32).eps * 2
    )
    # and the residual itself stays within one quantization step
    assert np.max(np.abs(err)) < 0.05


@pytest.mark.parametrize("R", [3, 9])
def test_ipls_aggregate_batched_q_matches_ref(R):
    """Fused dequantize-aggregate kernel vs its jnp oracle on real wire
    codes, including a zero-contributor row and a masked-out owner."""
    from repro.core.wire import _np_quantize, num_blocks
    from repro.kernels.ipls_aggregate.ops import aggregate_batched_q
    from repro.kernels.ipls_aggregate.ref import ipls_aggregate_batched_q_ref

    K, N = 4, 2500
    nb = num_blocks(N)
    w = jnp.asarray(RNG.standard_normal((K, N)), jnp.float32)
    own = jnp.asarray(RNG.standard_normal((K, N)), jnp.float32)
    q = np.zeros((K, R, N), np.int8)
    s = np.zeros((K, R, nb), np.float32)
    for k in range(K):
        for r in range(R):
            x = (RNG.standard_normal(N) * 0.1).astype(np.float32)
            qq, s[k, r], _ = _np_quantize(x, np.zeros(N, np.float32))
            q[k, r] = qq[:N]
    m = jnp.asarray(RNG.integers(0, 2, (K, R)), jnp.float32)
    m = m.at[2].set(0.0)
    om = jnp.ones((K,), jnp.float32).at[2].set(0.0)
    eps = jnp.asarray(RNG.uniform(0.1, 1.0, K), jnp.float32)
    got = aggregate_batched_q(w, own, jnp.asarray(q), jnp.asarray(s), m, om, eps)
    ref = ipls_aggregate_batched_q_ref(w, own, jnp.asarray(q), jnp.asarray(s), m, om, eps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(w[2]))
